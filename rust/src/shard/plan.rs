//! The deterministic shard planner: stable hashes map nodes to cells to
//! shards, and the assignment is written out as a schema-versioned shard
//! manifest (the pb-sharder idiom: the partition is an auditable document,
//! not an accident of thread scheduling).
//!
//! Two layers, both pure functions of names and counts:
//!
//! * **cell** — the unit of simulation state. One cell per topology node;
//!   cell `i` owns node `i` and every service whose name hashes to `i`.
//!   Cells exist at *every* shard count (including 1), which is what makes
//!   reports byte-identical: changing `--shards` never moves state, only
//!   which worker thread drives it.
//! * **shard** — the unit of execution. `stable_hash("node-<i>") % shards`
//!   groups cells onto worker threads; a shard may own zero cells (more
//!   shards than nodes is legal and harmless).

use crate::cluster::topology::Topology;
use crate::util::json::Json;

/// Version of the shard-manifest JSON layout.
pub const MANIFEST_SCHEMA_VERSION: u64 = 1;
/// Document discriminator for shard manifests.
pub const MANIFEST_KIND: &str = "kinetic-shard-manifest";

/// FNV-1a over the bytes of `s` — a stable, dependency-free hash that never
/// changes across platforms or compiler versions, so shard assignment is
/// part of the repo's contract rather than `DefaultHasher`'s whim.
pub fn stable_hash(s: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// The deterministic partition of one run: cells (one per node) and their
/// shard assignment.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardPlan {
    /// Worker-thread count the plan was built for.
    pub shards: u32,
    /// Shard owning each cell, indexed by cell (== node) index.
    pub shard_of: Vec<u32>,
}

impl ShardPlan {
    /// Plans `shards` workers over the topology: cell `i` is node `i`,
    /// assigned to `stable_hash("node-<i>") % shards`.
    pub fn new(topology: &Topology, shards: u32) -> ShardPlan {
        assert!(shards > 0, "shard count must be >= 1");
        let shard_of = (0..topology.len())
            .map(|i| (stable_hash(&format!("node-{i}")) % u64::from(shards)) as u32)
            .collect();
        ShardPlan { shards, shard_of }
    }

    /// Number of cells (== topology nodes).
    pub fn cells(&self) -> usize {
        self.shard_of.len()
    }

    /// Home cell of a service: `stable_hash(name) % cells`. Every arrival
    /// for the service is injected there, at any shard count.
    pub fn cell_of(&self, service: &str) -> usize {
        (stable_hash(service) % self.shard_of.len() as u64) as usize
    }

    /// The schema-versioned shard manifest: one entry per cell with its
    /// node, shard, and the home services assigned to it.
    pub fn manifest(&self, services: &[String]) -> Json {
        let cells = (0..self.cells()).map(|i| {
            let homed: Vec<Json> = services
                .iter()
                .filter(|s| self.cell_of(s) == i)
                .map(|s| s.as_str().into())
                .collect();
            Json::obj(vec![
                ("cell", (i as u64).into()),
                ("node", (i as u64).into()),
                ("shard", u64::from(self.shard_of[i]).into()),
                ("services", Json::Arr(homed)),
            ])
        });
        Json::obj(vec![
            ("kind", MANIFEST_KIND.into()),
            ("schema_version", MANIFEST_SCHEMA_VERSION.into()),
            ("shards", u64::from(self.shards).into()),
            ("cells", Json::arr(cells)),
        ])
    }

    /// Rebuilds a plan from a manifest, validating kind and version.
    pub fn from_manifest(j: &Json) -> Result<ShardPlan, String> {
        let kind = j.req_str("kind").map_err(|e| e.to_string())?;
        if kind != MANIFEST_KIND {
            return Err(format!("kind '{kind}' is not '{MANIFEST_KIND}'"));
        }
        let version = j.req_u64("schema_version").map_err(|e| e.to_string())?;
        if version != MANIFEST_SCHEMA_VERSION {
            return Err(format!(
                "schema_version {version} unsupported (expected {MANIFEST_SCHEMA_VERSION})"
            ));
        }
        let shards = j.req_u64("shards").map_err(|e| e.to_string())?;
        if shards == 0 {
            return Err("'shards' must be >= 1".to_string());
        }
        let cells = j.req_arr("cells").map_err(|e| e.to_string())?;
        let mut shard_of = Vec::with_capacity(cells.len());
        for (i, c) in cells.iter().enumerate() {
            let ctx = |e: crate::util::json::JsonError| format!("cells[{i}]: {e}");
            let cell = c.req_u64("cell").map_err(ctx)?;
            if cell != i as u64 {
                return Err(format!("cells[{i}] has cell index {cell}"));
            }
            let shard = c.req_u64("shard").map_err(ctx)?;
            if shard >= shards {
                return Err(format!("cells[{i}] assigned to shard {shard} of {shards}"));
            }
            shard_of.push(shard as u32);
        }
        Ok(ShardPlan {
            shards: shards as u32,
            shard_of,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stable_hash_is_pinned() {
        // FNV-1a reference vectors: the assignment contract must never move.
        assert_eq!(stable_hash(""), 0xcbf29ce484222325);
        assert_eq!(stable_hash("a"), 0xaf63dc4c8601ec8c);
        assert_eq!(stable_hash("node-0"), stable_hash("node-0"));
        assert_ne!(stable_hash("node-0"), stable_hash("node-1"));
    }

    #[test]
    fn assignment_is_stable_and_shard_count_independent_for_cells() {
        let topo = Topology::uniform_paper(8);
        let p1 = ShardPlan::new(&topo, 1);
        let p4 = ShardPlan::new(&topo, 4);
        assert_eq!(p1.cells(), 8);
        assert_eq!(p4.cells(), 8);
        // Cell homing ignores the shard count entirely.
        for svc in ["fn-0", "fn-1", "helloworld"] {
            assert_eq!(p1.cell_of(svc), p4.cell_of(svc));
        }
        // Everything lands on shard 0 at shards=1.
        assert!(p1.shard_of.iter().all(|&s| s == 0));
        assert!(p4.shard_of.iter().all(|&s| s < 4));
        // Re-planning is bit-identical.
        assert_eq!(p4, ShardPlan::new(&topo, 4));
    }

    #[test]
    fn manifest_round_trips() {
        let topo = Topology::uniform_paper(3);
        let plan = ShardPlan::new(&topo, 2);
        let services = vec!["fn-0".to_string(), "fn-1".to_string(), "fn-2".to_string()];
        let j = plan.manifest(&services);
        let text = j.to_string_pretty();
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(ShardPlan::from_manifest(&parsed).unwrap(), plan);
        // Every service appears in exactly one cell.
        let cells = parsed.req_arr("cells").unwrap();
        let mut seen = 0;
        for c in cells {
            seen += c.req_arr("services").unwrap().len();
        }
        assert_eq!(seen, services.len());
    }

    #[test]
    fn manifest_rejects_bad_documents() {
        let topo = Topology::uniform_paper(2);
        let plan = ShardPlan::new(&topo, 2);
        let mut j = plan.manifest(&[]);
        if let Json::Obj(m) = &mut j {
            m.insert("schema_version".to_string(), 99u64.into());
        }
        assert!(ShardPlan::from_manifest(&j)
            .unwrap_err()
            .contains("schema_version"));
        let mut j = plan.manifest(&[]);
        if let Json::Obj(m) = &mut j {
            m.insert("kind".to_string(), "something-else".into());
        }
        assert!(ShardPlan::from_manifest(&j).unwrap_err().contains("kind"));
    }

    #[test]
    fn more_shards_than_cells_leaves_some_shards_empty() {
        let topo = Topology::uniform_paper(2);
        let plan = ShardPlan::new(&topo, 16);
        assert_eq!(plan.cells(), 2);
        let used: std::collections::BTreeSet<u32> = plan.shard_of.iter().copied().collect();
        assert!(used.len() <= 2, "at most one shard per cell is populated");
    }
}
