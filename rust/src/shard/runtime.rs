//! The sharded multi-coordinator runtime: one cell (node + coordinator +
//! calendar-queue engine) per topology node, driven in lockstep time
//! windows, with shards of cells running on scoped worker threads.
//!
//! ## Protocol
//!
//! Conservative (Chandy–Misra style) synchronization over a fixed global
//! window ladder:
//!
//! 1. Every cell settles its deploy locally; its settle instant becomes
//!    the origin of *global* time for that cell (`g = local − settle`).
//! 2. Each round, the driver peeks every cell's earliest pending event and
//!    jumps the window start to the earliest one (dead windows are
//!    skipped, not simulated). The window is `[start, start + L]` where
//!    `L` is the lookahead.
//! 3. Cells run `run_until(settle + window_end)` — grouped by shard, one
//!    scoped thread per non-empty shard. The scope join is the barrier.
//! 4. At the barrier, cross-cell messages (crash-driven pod reschedules)
//!    are drained from every cell outbox in deterministic order, a target
//!    cell is picked by a pure function of cell state, and the delivery is
//!    scheduled in the target's queue at `emit + L` — which is `>=` the
//!    window end, so no cell ever receives an event from its past.
//!
//! ## Lookahead
//!
//! `L = StartupParams::schedule_ms` — the kube-scheduler decision/binding
//! stage, the first stage of *any* cross-cell pod placement. A crash
//! escalated at `t` cannot materially affect a sibling cell before
//! `t + L`, so delivering at exactly `t + L` loses nothing.
//!
//! ## Why reports are byte-identical at any shard count
//!
//! Cells and their seeds, service homing, arrival streams, fault splits,
//! the window ladder, outbox ordering and target choice are all pure
//! functions of the spec — never of the shard count. Shards only decide
//! which worker thread calls `run_until` on a cell, and cells share no
//! mutable state, so `--shards 1`, `2` and `4` execute identical event
//! sequences per cell and merge in the same canonical order.

use std::collections::BTreeMap;

use crate::cluster::topology::Topology;
use crate::coordinator::event::Event;
use crate::coordinator::platform::{Simulation, XShardMsg};
use crate::coordinator::service::Service;
use crate::experiments::fleet::{FleetConfig, FleetRow, FLEET_MIX};
use crate::faults::FaultsConfig;
use crate::knative::config::RevisionConfig;
use crate::loadgen::arrival::Arrival;
use crate::obs::{ObsBundle, ObserveConfig};
use crate::policy::{PlatformParams, Policy};
use crate::shard::plan::ShardPlan;
use crate::simclock::SimTime;
use crate::trace::generator::{TraceEvent, TraceGenerator};
use crate::trace::replay::{ReplayConfig, ReplayReport};
use crate::util::stats::Samples;
use crate::workload::registry::{WorkloadKind, WorkloadProfile};

/// One cell: a full platform over a single node, plus the local settle
/// instant that anchors it to global time.
struct Cell {
    sim: Simulation,
    settle: SimTime,
}

impl Cell {
    fn next_global(&mut self) -> Option<SimTime> {
        let settle = self.settle;
        self.sim
            .engine
            .next_at()
            .map(|at| at.saturating_sub(settle))
    }
}

/// What a service looked like at deploy time — enough to stamp a replica
/// (min-scale zero) into a sibling cell when a crash reschedules across
/// the shard boundary.
struct ServiceTemplate {
    profile: WorkloadProfile,
    policy: Policy,
    rc: RevisionConfig,
}

/// Mixes the cell index into the scenario seed (splitmix64's golden-ratio
/// increment) so per-cell RNG streams are decorrelated but depend only on
/// (seed, cell) — never on the shard count.
fn cell_seed(seed: u64, cell: usize) -> u64 {
    seed ^ 0x9E3779B97F4A7C15u64.wrapping_mul(cell as u64 + 1)
}

/// The conservative lookahead: the scheduler decision/binding stage, the
/// first stage of any cross-cell pod placement.
fn lookahead(params: &PlatformParams) -> SimTime {
    SimTime::from_millis_f64(params.startup.schedule_ms)
}

/// Builds one armed cell per topology node.
fn build_cells(topology: &Topology, seed: u64) -> Vec<Cell> {
    topology
        .shapes()
        .iter()
        .enumerate()
        .map(|(i, shape)| {
            let params = PlatformParams::with_seed(cell_seed(seed, i));
            let mut sim =
                Simulation::fleet_with_params(Topology::heterogeneous(vec![shape.clone()]), params);
            sim.world.arm_xshard_outbox();
            Cell {
                sim,
                settle: SimTime::ZERO,
            }
        })
        .collect()
}

/// Projects the global fault schedule onto one cell: crash/straggler
/// entries for node `i` become entries for the cell's only node (index 0);
/// the global knobs (inflation, resize failures, crash policy) apply
/// everywhere. Node ids were validated against the *global* topology by
/// the scenario compiler before this projection.
fn local_faults(cfg: &FaultsConfig, cell: u32) -> FaultsConfig {
    FaultsConfig {
        node_crashes: cfg
            .node_crashes
            .iter()
            .filter(|c| c.node == cell)
            .map(|c| crate::faults::NodeCrash { node: 0, ..*c })
            .collect(),
        crash_requests: cfg.crash_requests,
        stragglers: cfg
            .stragglers
            .iter()
            .filter(|s| s.node == cell)
            .map(|s| crate::faults::Straggler { node: 0, ..*s })
            .collect(),
        startup_inflation: cfg.startup_inflation,
        resize_failure_p: cfg.resize_failure_p,
    }
}

/// Runs one window on every cell, shard groups in parallel. The scope
/// join is the window barrier.
fn run_window(cells: &mut [Cell], plan: &ShardPlan, window_end: SimTime) {
    let mut groups: Vec<Vec<&mut Cell>> = (0..plan.shards as usize).map(|_| Vec::new()).collect();
    for (i, cell) in cells.iter_mut().enumerate() {
        groups[plan.shard_of[i] as usize].push(cell);
    }
    let mut live: Vec<Vec<&mut Cell>> = groups.into_iter().filter(|g| !g.is_empty()).collect();
    if live.len() <= 1 {
        // One populated shard (or none): no threads to spawn.
        for group in &mut live {
            for cell in group.iter_mut() {
                let deadline = cell.settle + window_end;
                cell.sim.run_until(deadline);
            }
        }
        return;
    }
    std::thread::scope(|s| {
        for group in live {
            s.spawn(move || {
                for cell in group {
                    let deadline = cell.settle + window_end;
                    cell.sim.run_until(deadline);
                }
            });
        }
    });
}

/// Picks the reschedule target for a message from `src`: the sibling cell
/// whose node is up with the most free CPU, ties to the lowest cell index.
/// A pure function of cell state, so the choice is shard-count
/// independent.
fn pick_target(cells: &[Cell], src: usize) -> Option<usize> {
    let mut best: Option<(u64, usize)> = None;
    for (i, cell) in cells.iter().enumerate() {
        if i == src {
            continue;
        }
        let node = &cell.sim.world.cluster.nodes()[0];
        if !node.up() {
            continue;
        }
        let free = node.capacity().cpu.0.saturating_sub(node.reserved().cpu.0);
        match best {
            Some((best_free, _)) if free <= best_free => {}
            _ => best = Some((free, i)),
        }
    }
    best.map(|(_, i)| i)
}

/// Makes sure `service` exists in `cell`, stamping a min-scale-zero
/// replica from its deploy-time template if not. The replica hosts
/// rescheduled replacement pods; traffic keeps flowing to the home cell.
fn ensure_service(cell: &mut Cell, service: &str, templates: &BTreeMap<String, ServiceTemplate>) {
    if cell.sim.world.services.contains_key(service) {
        return;
    }
    let Some(t) = templates.get(service) else { return };
    let mut rc = t.rc.clone();
    rc.min_scale = 0;
    cell.sim
        .deploy_service(Service::with_config(service, t.profile.clone(), t.policy, rc));
}

/// Drains every cell's cross-shard outbox at a window barrier and
/// schedules each message into its target cell at `emit + L` (>= the
/// window end by construction, so targets never see the past).
fn deliver(
    cells: &mut [Cell],
    templates: &BTreeMap<String, ServiceTemplate>,
    lookahead: SimTime,
) {
    // (emit in global time, source cell, message) — collected in cell
    // order, stably sorted by (emit, source), so delivery order is a pure
    // function of simulation state.
    let mut batch: Vec<(SimTime, usize, XShardMsg)> = Vec::new();
    for (i, cell) in cells.iter_mut().enumerate() {
        let settle = cell.settle;
        for msg in cell.sim.world.take_xshard_msgs() {
            batch.push((msg.at.saturating_sub(settle), i, msg));
        }
    }
    if batch.is_empty() {
        return;
    }
    batch.sort_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
    for (emit, src, msg) in batch {
        match pick_target(cells, src) {
            Some(target) => {
                ensure_service(&mut cells[target], &msg.service, templates);
                // Interned ids are per-cell: translate the wire-format
                // service name into the *target* cell's id space here at
                // the barrier. No template ⇒ no replica can exist, and
                // the old name-addressed event would have no-opped too.
                let Some(svc_id) = cells[target].sim.world.services.id_of(&msg.service) else {
                    continue;
                };
                let at = cells[target].settle + emit + lookahead;
                cells[target].sim.engine.schedule_at(
                    at,
                    Event::XShardReschedule {
                        service: svc_id,
                        pods: msg.pods,
                    },
                );
            }
            // The whole fleet is down: nothing can host the replacements.
            None => cells[src].sim.world.metrics.pods_unschedulable += u64::from(msg.pods),
        }
    }
}

/// The lockstep window loop: run windows until every cell's queue drains.
/// Progress is guaranteed — the window start jumps to the earliest pending
/// event, which is then strictly inside the window.
fn drive(
    cells: &mut [Cell],
    plan: &ShardPlan,
    templates: &BTreeMap<String, ServiceTemplate>,
    lookahead: SimTime,
) {
    loop {
        let next = cells.iter_mut().filter_map(Cell::next_global).min();
        let Some(window_start) = next else { break };
        run_window(cells, plan, window_start + lookahead);
        deliver(cells, templates, lookahead);
    }
}

/// Canonical merge of per-cell service metrics: cells in node order,
/// services in BTreeMap order within each — so floats sum in a fixed
/// order and the merged report is bit-identical at any shard count.
struct Merged {
    lat: Samples,
    completed: u64,
    failed: u64,
    cold: u64,
    ups: u64,
    spec_ups: u64,
    mispred: u64,
    avg_committed_mcpu: f64,
    pods_created: u64,
    pods_unschedulable: u64,
    pods_evicted: u64,
    pods_rescheduled: u64,
    resize_failures: u64,
    /// Longest per-cell measured span (now − settle).
    wall: SimTime,
}

fn merge(cells: &[Cell]) -> Merged {
    let mut m = Merged {
        lat: Samples::new(),
        completed: 0,
        failed: 0,
        cold: 0,
        ups: 0,
        spec_ups: 0,
        mispred: 0,
        avg_committed_mcpu: 0.0,
        pods_created: 0,
        pods_unschedulable: 0,
        pods_evicted: 0,
        pods_rescheduled: 0,
        resize_failures: 0,
        wall: SimTime::ZERO,
    };
    for cell in cells.iter() {
        // The window-partition-invariant end-of-run clock: the engine's
        // `now` lands on the final sync-window deadline (and observed
        // runs window past the workload on trailing ObsTicks), so merge
        // at the last real event instead — identical whether or not the
        // run was observed, and at any shard count.
        let now = cell
            .sim
            .world
            .obs_end_clock()
            .unwrap_or_else(|| cell.sim.engine.last_processed_at());
        let metrics = &cell.sim.world.metrics;
        for (_, s) in metrics.services() {
            m.completed += s.completed;
            m.failed += s.failed;
            m.cold += s.cold_starts;
            m.ups += s.inplace_scale_ups;
            m.spec_ups += s.speculative_resizes;
            m.mispred += s.mispredictions;
            for &v in s.latency_ms.values() {
                m.lat.record(v);
            }
        }
        m.avg_committed_mcpu += metrics.committed_cpu.average_mcpu(now);
        m.pods_created += metrics.pods_created;
        m.pods_unschedulable += metrics.pods_unschedulable;
        m.pods_evicted += metrics.pods_evicted;
        m.pods_rescheduled += metrics.pods_rescheduled;
        m.resize_failures += metrics.resize_failures;
        m.wall = m.wall.max(now.saturating_sub(cell.settle));
    }
    m
}

/// Arms every cell's observation plane with the *scenario* seed (not the
/// cell seed): the sampler keys on (seed, service name) with per-service
/// counters, so arming each cell identically reproduces the serial path's
/// sampling decisions no matter where a service is homed.
fn arm_cells(cells: &mut [Cell], observe: Option<&ObserveConfig>, seed: u64) {
    let Some(oc) = observe else { return };
    for cell in cells.iter_mut() {
        let origin = cell.sim.engine.now();
        cell.sim.world.arm_obs(oc.clone(), seed, origin);
        if oc.timeline {
            cell.sim.engine.schedule_in(oc.timeline_cadence, Event::ObsTick);
        }
    }
}

/// Harvests per-cell bundles in canonical cell (node index) order and
/// merges them, so the observation output is identical at any `--shards N`.
fn harvest_cells(cells: &mut [Cell], observed: bool) -> Option<ObsBundle> {
    if !observed {
        return None;
    }
    let bundles: Vec<ObsBundle> = cells
        .iter_mut()
        .filter_map(|c| {
            let queue = c.sim.engine.queue_stats();
            let processed = c.sim.engine.processed();
            c.sim.world.take_obs().map(|o| o.finish(queue, processed))
        })
        .collect();
    Some(ObsBundle::merge(bundles))
}

/// Sharded counterpart of [`fleet::run_policy`](crate::experiments::fleet::run_policy):
/// the same synthetic open-loop fleet, partitioned one cell per node.
pub fn run_policy_sharded(cfg: &FleetConfig, policy: Policy, shards: u32) -> FleetRow {
    run_policy_sharded_counting(cfg, policy, shards).0
}

/// Like [`run_policy_sharded`] but also returns total engine events
/// processed across every cell (the bench ladder's throughput numerator).
pub fn run_policy_sharded_counting(
    cfg: &FleetConfig,
    policy: Policy,
    shards: u32,
) -> (FleetRow, u64) {
    let (row, events, _) = run_policy_sharded_observed(cfg, policy, shards, None);
    (row, events)
}

/// [`run_policy_sharded_counting`] plus an optional observation plane,
/// armed per cell and merged in canonical cell order.
pub fn run_policy_sharded_observed(
    cfg: &FleetConfig,
    policy: Policy,
    shards: u32,
    observe: Option<&ObserveConfig>,
) -> (FleetRow, u64, Option<ObsBundle>) {
    let plan = ShardPlan::new(&cfg.topology, shards);
    let la = lookahead(&PlatformParams::with_seed(cfg.seed));
    let mut cells = build_cells(&cfg.topology, cfg.seed);
    for cell in cells.iter_mut() {
        cell.sim.world.routing = cfg.routing;
        cell.sim.world.hybrid_weights = cfg.hybrid;
    }

    // Deploy every tenant into its home cell, keeping the template for
    // cross-cell replicas.
    let mix: &[WorkloadKind] = if cfg.mix.is_empty() { &FLEET_MIX } else { &cfg.mix };
    let mut templates: BTreeMap<String, ServiceTemplate> = BTreeMap::new();
    for i in 0..cfg.services {
        let kind = mix[i % mix.len()];
        let mut rc = policy.revision_config();
        cfg.knobs.apply(&mut rc);
        cfg.forecast.apply(&mut rc, policy);
        let name = format!("fn-{i}");
        let profile = WorkloadProfile::paper(kind);
        let home = plan.cell_of(&name);
        cells[home]
            .sim
            .deploy_service(Service::with_config(&name, profile.clone(), policy, rc.clone()));
        templates.insert(name, ServiceTemplate { profile, policy, rc });
    }
    for cell in cells.iter_mut() {
        cell.sim.run(); // settle: min-scale pods up / in-place pods parked
        cell.settle = cell.sim.now();
    }
    arm_cells(&mut cells, observe, cfg.seed);

    // Open-loop Poisson stream per tenant — the exact per-service seeds of
    // the serial path, injected upfront into the home cell.
    for i in 0..cfg.services {
        let mut rng = crate::util::rng::Rng::new(cfg.seed ^ (0xF1EE7 + i as u64));
        let arrival = Arrival::Poisson {
            rate_per_sec: cfg.rate_per_service,
        };
        let name = format!("fn-{i}");
        let home = plan.cell_of(&name);
        let start = cells[home].settle;
        for t in arrival.times(cfg.horizon, &mut rng) {
            cells[home].sim.submit_at(start + t, &name);
        }
    }

    for (i, cell) in cells.iter_mut().enumerate() {
        let local = local_faults(&cfg.faults, i as u32);
        let engine = &mut cell.sim.engine;
        cell.sim.world.install_faults(engine, &local);
    }

    drive(&mut cells, &plan, &templates, la);

    // Merge before harvesting: the merge clock reads the observation
    // state's last-real-event time, which take_obs detaches.
    let mut m = merge(&cells);
    let bundle = harvest_cells(&mut cells, observe.is_some());
    let events = cells.iter().map(|c| c.sim.engine.processed()).sum();
    let row = FleetRow {
        policy,
        routing: cfg.routing,
        nodes: cfg.topology.len(),
        services: cfg.services,
        completed: m.completed,
        failed: m.failed,
        mean_ms: m.lat.mean(),
        p50_ms: m.lat.percentile(50.0),
        p99_ms: m.lat.percentile(99.0),
        cold_starts: m.cold,
        inplace_scale_ups: m.ups,
        speculative_resizes: m.spec_ups,
        mispredictions: m.mispred,
        avg_committed_mcpu: m.avg_committed_mcpu,
        pods_created: m.pods_created,
        pods_unschedulable: m.pods_unschedulable,
        pods_evicted: m.pods_evicted,
        pods_rescheduled: m.pods_rescheduled,
        resize_failures: m.resize_failures,
    };
    (row, events, bundle)
}

/// Sharded counterpart of [`replay_with`](crate::trace::replay::replay_with):
/// the same trace replay, one cell per topology node, functions homed by
/// rank name.
pub fn replay_sharded(trace: &[TraceEvent], cfg: &ReplayConfig, shards: u32) -> ReplayReport {
    replay_sharded_observed(trace, cfg, shards, None).0
}

/// [`replay_sharded`] plus an optional observation plane, armed per cell
/// and merged in canonical cell order.
pub fn replay_sharded_observed(
    trace: &[TraceEvent],
    cfg: &ReplayConfig,
    shards: u32,
    observe: Option<&ObserveConfig>,
) -> (ReplayReport, Option<ObsBundle>) {
    let plan = ShardPlan::new(&cfg.topology, shards);
    let la = lookahead(&PlatformParams::with_seed(cfg.seed));
    let mut cells = build_cells(&cfg.topology, cfg.seed);
    for cell in cells.iter_mut() {
        cell.sim.world.routing = cfg.routing;
        cell.sim.world.hybrid_weights = cfg.hybrid;
    }

    let mut names: BTreeMap<usize, String> = BTreeMap::new();
    let mut templates: BTreeMap<String, ServiceTemplate> = BTreeMap::new();
    for rank in 0..cfg.functions {
        let name = format!("fn-{rank}");
        let mut rc = cfg.policy.revision_config();
        cfg.knobs.apply(&mut rc);
        cfg.forecast.apply(&mut rc, cfg.policy);
        let profile = TraceGenerator::profile_for(rank);
        let home = plan.cell_of(&name);
        cells[home].sim.deploy_service(Service::with_config(
            &name,
            profile.clone(),
            cfg.policy,
            rc.clone(),
        ));
        templates.insert(
            name.clone(),
            ServiceTemplate {
                profile,
                policy: cfg.policy,
                rc,
            },
        );
        names.insert(rank, name);
    }
    for cell in cells.iter_mut() {
        cell.sim.run();
        cell.settle = cell.sim.now();
    }
    arm_cells(&mut cells, observe, cfg.seed);

    for ev in trace {
        let name = &names[&ev.function];
        let home = plan.cell_of(name);
        let start = cells[home].settle;
        cells[home].sim.submit_at(start + ev.at, name);
    }

    for (i, cell) in cells.iter_mut().enumerate() {
        let local = local_faults(&cfg.faults, i as u32);
        let engine = &mut cell.sim.engine;
        cell.sim.world.install_faults(engine, &local);
    }

    drive(&mut cells, &plan, &templates, la);

    // Merge before harvesting: the merge clock reads the observation
    // state's last-real-event time, which take_obs detaches.
    let mut m = merge(&cells);
    let bundle = harvest_cells(&mut cells, observe.is_some());
    let report = ReplayReport {
        policy: cfg.policy,
        completed: m.completed,
        failed: m.failed,
        mean_ms: m.lat.mean(),
        p50_ms: m.lat.percentile(50.0),
        p99_ms: m.lat.percentile(99.0),
        cold_starts: m.cold,
        inplace_scale_ups: m.ups,
        speculative_resizes: m.spec_ups,
        mispredictions: m.mispred,
        avg_committed_mcpu: m.avg_committed_mcpu,
        pods_created: m.pods_created,
        pods_unschedulable: m.pods_unschedulable,
        pods_evicted: m.pods_evicted,
        pods_rescheduled: m.pods_rescheduled,
        resize_failures: m.resize_failures,
        wall: m.wall,
    };
    (report, bundle)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::accounting::RoutingPolicy;

    fn tiny_cfg() -> FleetConfig {
        FleetConfig {
            services: 6,
            rate_per_service: 0.2,
            horizon: SimTime::from_secs(20),
            routing: RoutingPolicy::LeastLoaded,
            ..FleetConfig::base(Topology::uniform_paper(3), 42)
        }
    }

    #[test]
    fn fleet_rows_are_identical_across_shard_counts() {
        let cfg = tiny_cfg();
        for policy in [Policy::InPlace, Policy::Warm] {
            let one = run_policy_sharded(&cfg, policy, 1);
            let two = run_policy_sharded(&cfg, policy, 2);
            let four = run_policy_sharded(&cfg, policy, 4);
            assert_eq!(format!("{one:?}"), format!("{two:?}"), "{policy:?} 1 vs 2");
            assert_eq!(format!("{one:?}"), format!("{four:?}"), "{policy:?} 1 vs 4");
            assert!(one.completed > 0, "{policy:?} completed nothing");
            assert_eq!(one.failed, 0);
        }
    }

    #[test]
    fn crash_escalation_reschedules_into_a_sibling_cell() {
        let mut cfg = tiny_cfg();
        cfg.faults = FaultsConfig {
            node_crashes: vec![crate::faults::NodeCrash {
                node: 0,
                at: SimTime::from_secs(2),
                down: SimTime::from_secs(60),
            }],
            crash_requests: crate::faults::CrashRequestPolicy::Fail,
            ..FaultsConfig::default()
        };
        let one = run_policy_sharded(&cfg, Policy::Warm, 1);
        let four = run_policy_sharded(&cfg, Policy::Warm, 4);
        assert_eq!(one.pods_evicted, four.pods_evicted);
        assert_eq!(one.pods_rescheduled, four.pods_rescheduled);
        assert!(one.pods_evicted > 0, "the crash must evict something");
        assert!(
            one.pods_rescheduled > 0,
            "replacements must land in a sibling cell"
        );
    }

    #[test]
    fn more_shards_than_cells_is_harmless() {
        let cfg = tiny_cfg();
        let one = run_policy_sharded(&cfg, Policy::InPlace, 1);
        let many = run_policy_sharded(&cfg, Policy::InPlace, 16);
        assert_eq!(format!("{one:?}"), format!("{many:?}"));
    }
}
