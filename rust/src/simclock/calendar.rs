//! Calendar-queue scheduler: the O(1)-amortized priority queue behind
//! [`Engine`](super::Engine).
//!
//! A classic calendar queue (Brown, CACM '88) hashed on virtual time:
//! events land in `bucket = (at / width) % buckets`, and the pop path scans
//! one *rotation* of bucket windows starting at the last-popped time. Bucket
//! windows within a rotation are disjoint and ascending, so the first bucket
//! holding an entry inside its current window holds the global minimum —
//! schedule and pop are O(1) amortized while the queue keeps ~one live
//! entry per bucket, which the adaptive resize maintains.
//!
//! Two departures from the textbook structure, both driven by the engine's
//! determinism contract:
//!
//! * **Exact tie order.** Every entry carries the engine's insertion `seq`;
//!   minima compare on `(at, seq)`, so same-time events pop in schedule
//!   order — bit-identical to the BinaryHeap engine it replaced (pinned by
//!   `tests/engine_diff.rs` against the retained oracle).
//! * **Slot-based generation-stamped cancellation.** A cancel handle is
//!   `(slot, generation)` into a slab reused through a free list. Cancelling
//!   disarms the slot (O(1), exact `len()`), and the entry itself evaporates
//!   lazily the first time a scan touches it; popping an entry frees its
//!   slot and bumps the generation, so a stale handle — including one for an
//!   event that already fired — can never cancel the slot's next tenant.
//!   This replaces the old engine's grow-forever tombstone `IdHashSet`.

use super::clock::SimTime;

const MIN_BUCKETS: usize = 16;
/// Starting bucket width: 1 ms of virtual time.
const INITIAL_WIDTH_NS: u64 = 1_000_000;

/// Internal activity counters for the self-profiling plane — plain `u64`
/// bumps on paths the queue takes anyway, so they cost nothing measurable
/// and never affect scheduling behavior.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct QueueStats {
    /// Adaptive resizes (grow + shrink re-hashes).
    pub rebuilds: u64,
    /// Entries examined by bucket scans (`find_min` work).
    pub entry_scans: u64,
    /// Largest bucket occupancy ever reached.
    pub max_bucket: u64,
}

struct Entry<T> {
    at: u64,
    seq: u64,
    slot: u32,
    item: T,
}

struct Slot {
    generation: u32,
    armed: bool,
}

/// Location of the current minimum, memoized between `peek_at` and `pop`.
#[derive(Clone, Copy)]
struct MinLoc {
    bucket: usize,
    pos: usize,
    at: u64,
    seq: u64,
}

/// The bucket-array priority queue. Entries are `(at, seq, item)`; handles
/// returned by [`schedule`](CalendarQueue::schedule) are `(slot, generation)`
/// pairs for O(1) cancellation.
pub struct CalendarQueue<T> {
    buckets: Vec<Vec<Entry<T>>>,
    /// Virtual nanoseconds each bucket spans in one rotation.
    width: u64,
    /// Physical entries across all buckets, including cancelled ones not
    /// yet purged by a scan.
    queued: usize,
    /// Armed (schedulable) entries — `len()` is exact by construction.
    live: usize,
    /// Monotone lower bound on every queued `at`: the virtual time of the
    /// last popped entry. Rotation scans start at its bucket.
    floor: u64,
    slots: Vec<Slot>,
    free: Vec<u32>,
    cached: Option<MinLoc>,
    stats: QueueStats,
}

impl<T> Default for CalendarQueue<T> {
    fn default() -> Self {
        CalendarQueue::new()
    }
}

impl<T> CalendarQueue<T> {
    pub fn new() -> CalendarQueue<T> {
        CalendarQueue {
            buckets: (0..MIN_BUCKETS).map(|_| Vec::new()).collect(),
            width: INITIAL_WIDTH_NS,
            queued: 0,
            live: 0,
            floor: 0,
            slots: Vec::new(),
            free: Vec::new(),
            cached: None,
            stats: QueueStats::default(),
        }
    }

    /// Snapshot of the internal activity counters.
    pub fn stats(&self) -> QueueStats {
        self.stats
    }

    /// Live (non-cancelled) entries.
    pub fn len(&self) -> usize {
        self.live
    }

    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    /// Physical slot-table size — bounded by peak concurrency, not by total
    /// events or cancellations (the tombstone-leak regression tripwire).
    pub fn slot_count(&self) -> usize {
        self.slots.len()
    }

    /// Inserts `item` at virtual time `at` with tie-break rank `seq`
    /// (callers must pass strictly increasing `seq` values and `at >=` the
    /// last popped time). Returns the `(slot, generation)` cancel handle.
    pub fn schedule(&mut self, at: SimTime, seq: u64, item: T) -> (u32, u32) {
        if self.queued + 1 > self.buckets.len() * 2 {
            let n = self.buckets.len() * 2;
            self.rebuild(n);
        }
        let slot = match self.free.pop() {
            Some(s) => s,
            None => {
                self.slots.push(Slot {
                    generation: 0,
                    armed: false,
                });
                (self.slots.len() - 1) as u32
            }
        };
        self.slots[slot as usize].armed = true;
        let generation = self.slots[slot as usize].generation;
        let at = at.as_nanos();
        let b = self.bucket_of(at);
        self.buckets[b].push(Entry {
            at,
            seq,
            slot,
            item,
        });
        self.queued += 1;
        self.live += 1;
        self.stats.max_bucket = self.stats.max_bucket.max(self.buckets[b].len() as u64);
        // A pushed entry never shifts existing indices, so the memoized min
        // survives unless the newcomer beats it (equal `at` loses on seq).
        if self.cached.is_some_and(|c| at < c.at) {
            self.cached = None;
        }
        (slot, generation)
    }

    /// Disarms the entry behind `(slot, generation)`. Returns whether a
    /// live entry was cancelled; stale handles (already fired, already
    /// cancelled, slot since reused) are a no-op.
    pub fn cancel(&mut self, slot: u32, generation: u32) -> bool {
        match self.slots.get_mut(slot as usize) {
            Some(s) if s.armed && s.generation == generation => {
                s.armed = false;
                self.live -= 1;
                self.cached = None;
                true
            }
            _ => false,
        }
    }

    /// Virtual time of the earliest live entry, memoizing its location for
    /// the following `pop`.
    pub fn peek_at(&mut self) -> Option<SimTime> {
        if self.live == 0 {
            self.purge_if_dead();
            return None;
        }
        if self.cached.is_none() {
            self.cached = Some(self.find_min());
        }
        self.cached.map(|c| SimTime::from_nanos(c.at))
    }

    /// Removes and returns the earliest live entry (ties by `seq`).
    pub fn pop(&mut self) -> Option<(SimTime, T)> {
        if self.live == 0 {
            self.purge_if_dead();
            return None;
        }
        let loc = match self.cached.take() {
            Some(c) => c,
            None => self.find_min(),
        };
        let entry = self.buckets[loc.bucket].swap_remove(loc.pos);
        debug_assert!(entry.at == loc.at && entry.seq == loc.seq);
        self.queued -= 1;
        self.live -= 1;
        self.free_slot(entry.slot);
        self.floor = entry.at;
        if self.live * 8 < self.buckets.len() && self.buckets.len() > MIN_BUCKETS {
            let n = self.buckets.len() / 2;
            self.rebuild(n);
        }
        Some((SimTime::from_nanos(entry.at), entry.item))
    }

    fn bucket_of(&self, at: u64) -> usize {
        ((at / self.width) % self.buckets.len() as u64) as usize
    }

    fn free_slot(&mut self, slot: u32) {
        let s = &mut self.slots[slot as usize];
        s.armed = false;
        s.generation = s.generation.wrapping_add(1);
        self.free.push(slot);
    }

    /// Drops every remaining (necessarily cancelled) entry once the queue
    /// holds no live work, so tombstone memory never outlives a drain.
    fn purge_if_dead(&mut self) {
        if self.queued == 0 {
            return;
        }
        for bucket in &mut self.buckets {
            while let Some(e) = bucket.pop() {
                let s = &mut self.slots[e.slot as usize];
                s.armed = false;
                s.generation = s.generation.wrapping_add(1);
                self.free.push(e.slot);
            }
        }
        self.queued = 0;
        self.cached = None;
    }

    /// Locates the `(at, seq)`-minimum live entry. Requires `live > 0`.
    fn find_min(&mut self) -> MinLoc {
        debug_assert!(self.live > 0);
        let nb = self.buckets.len() as u64;
        let start = self.floor / self.width;
        // One rotation from the floor's bucket: windows are disjoint and
        // ascending, so the first bucket with an in-window entry wins.
        for step in 0..nb {
            let virt = start + step;
            let b = (virt % nb) as usize;
            let window_end = (virt as u128 + 1) * self.width as u128;
            if let Some(loc) = self.scan_bucket(b, Some(window_end)) {
                return loc;
            }
        }
        // Sparse regime: nothing lands inside the next full rotation (the
        // minimum is more than buckets×width ahead). Fall back to a global
        // scan — at most once per popped far-future event.
        let mut best: Option<MinLoc> = None;
        for b in 0..self.buckets.len() {
            if let Some(loc) = self.scan_bucket(b, None) {
                let better = match best {
                    None => true,
                    Some(c) => (loc.at, loc.seq) < (c.at, c.seq),
                };
                if better {
                    best = Some(loc);
                }
            }
        }
        best.expect("live > 0 implies an armed entry exists")
    }

    /// Scans bucket `b` for its `(at, seq)`-minimum armed entry, purging
    /// cancelled entries as it goes. With `window_end`, only entries below
    /// it qualify (the calendar-rotation window).
    fn scan_bucket(&mut self, b: usize, window_end: Option<u128>) -> Option<MinLoc> {
        let mut best: Option<MinLoc> = None;
        let mut i = 0;
        while i < self.buckets[b].len() {
            self.stats.entry_scans += 1;
            let e = &self.buckets[b][i];
            let (slot, at, seq) = (e.slot, e.at, e.seq);
            if !self.slots[slot as usize].armed {
                // Lazy tombstone purge. `swap_remove` moves the *last*
                // element into `i`; any memoized best sits at an index < i
                // and is unaffected.
                self.buckets[b].swap_remove(i);
                self.free_slot(slot);
                self.queued -= 1;
                continue;
            }
            let in_window = match window_end {
                None => true,
                Some(w) => (at as u128) < w,
            };
            let better = match best {
                None => true,
                Some(c) => (at, seq) < (c.at, c.seq),
            };
            if in_window && better {
                best = Some(MinLoc {
                    bucket: b,
                    pos: i,
                    at,
                    seq,
                });
            }
            i += 1;
        }
        best
    }

    /// Re-hashes every live entry into `nbuckets` buckets, re-fitting the
    /// bucket width to the live span (≈ one entry per bucket) and dropping
    /// cancelled entries outright.
    fn rebuild(&mut self, nbuckets: usize) {
        let nbuckets = nbuckets.max(MIN_BUCKETS);
        self.stats.rebuilds += 1;
        let mut entries: Vec<Entry<T>> = Vec::with_capacity(self.live);
        for bucket in &mut self.buckets {
            while let Some(e) = bucket.pop() {
                let s = &mut self.slots[e.slot as usize];
                if s.armed {
                    entries.push(e);
                } else {
                    s.generation = s.generation.wrapping_add(1);
                    self.free.push(e.slot);
                }
            }
        }
        self.queued = entries.len();
        debug_assert_eq!(self.queued, self.live);
        if entries.len() >= 2 {
            let mut lo = u64::MAX;
            let mut hi = 0u64;
            for e in &entries {
                lo = lo.min(e.at);
                hi = hi.max(e.at);
            }
            self.width = ((hi - lo) / entries.len() as u64).max(1);
        }
        self.buckets = (0..nbuckets).map(|_| Vec::new()).collect();
        for e in entries {
            let b = self.bucket_of(e.at);
            self.buckets[b].push(e);
        }
        self.cached = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain(q: &mut CalendarQueue<u32>) -> Vec<(u64, u32)> {
        let mut out = Vec::new();
        while let Some((at, item)) = q.pop() {
            out.push((at.as_nanos(), item));
        }
        out
    }

    #[test]
    fn pops_in_time_then_seq_order() {
        let mut q = CalendarQueue::new();
        q.schedule(SimTime::from_millis(30), 0, 0u32);
        q.schedule(SimTime::from_millis(10), 1, 1);
        q.schedule(SimTime::from_millis(10), 2, 2);
        q.schedule(SimTime::from_millis(20), 3, 3);
        assert_eq!(q.len(), 4);
        let order = drain(&mut q);
        assert_eq!(
            order,
            vec![
                (10_000_000, 1),
                (10_000_000, 2),
                (20_000_000, 3),
                (30_000_000, 0)
            ]
        );
    }

    #[test]
    fn cancel_is_exact_and_stale_handles_noop() {
        let mut q = CalendarQueue::new();
        let (s1, g1) = q.schedule(SimTime::from_millis(1), 0, 10u32);
        let (s2, g2) = q.schedule(SimTime::from_millis(2), 1, 20);
        assert!(q.cancel(s1, g1));
        assert_eq!(q.len(), 1);
        // Double-cancel and cancel-after-pop are no-ops.
        assert!(!q.cancel(s1, g1));
        assert_eq!(q.pop().map(|(_, v)| v), Some(20));
        assert!(!q.cancel(s2, g2));
        assert_eq!(q.len(), 0);
        assert!(q.pop().is_none());
    }

    #[test]
    fn stale_cancel_cannot_kill_a_reused_slot() {
        let mut q = CalendarQueue::new();
        let (s1, g1) = q.schedule(SimTime::from_millis(1), 0, 1u32);
        assert_eq!(q.pop().map(|(_, v)| v), Some(1));
        // The next schedule reuses the freed slot with a bumped generation.
        let (s2, _g2) = q.schedule(SimTime::from_millis(2), 1, 2);
        assert_eq!(s1, s2);
        assert!(!q.cancel(s1, g1), "stale handle must not cancel new tenant");
        assert_eq!(q.pop().map(|(_, v)| v), Some(2));
    }

    #[test]
    fn slot_table_stays_bounded_by_concurrency() {
        let mut q = CalendarQueue::new();
        for i in 0..10_000u64 {
            q.schedule(SimTime::from_nanos(i), i, 0u32);
            q.pop();
        }
        assert!(q.slot_count() <= 2, "slots={}", q.slot_count());
        // Cancellations recycle slots too once a scan purges them.
        let mut handles = Vec::new();
        for i in 0..100u64 {
            handles.push(q.schedule(SimTime::from_nanos(20_000 + i), 20_000 + i, 0u32));
        }
        for (s, g) in handles {
            q.cancel(s, g);
        }
        assert_eq!(q.len(), 0);
        assert!(q.pop().is_none()); // purges tombstones
        for i in 0..100u64 {
            q.schedule(SimTime::from_nanos(30_000 + i), 30_000 + i, 0u32);
        }
        assert!(q.slot_count() <= 102, "slots={}", q.slot_count());
    }

    #[test]
    fn grows_and_shrinks_through_rebuilds() {
        let mut q = CalendarQueue::new();
        // Far beyond 2×MIN_BUCKETS entries forces growth rebuilds.
        for i in 0..5_000u64 {
            q.schedule(SimTime::from_micros(i * 37 % 10_000), i, i as u32);
        }
        assert_eq!(q.len(), 5_000);
        let order = drain(&mut q);
        assert_eq!(order.len(), 5_000);
        assert!(order.windows(2).all(|w| w[0].0 <= w[1].0), "sorted by time");
    }

    #[test]
    fn far_future_entries_use_the_global_fallback() {
        let mut q = CalendarQueue::new();
        q.schedule(SimTime::from_nanos(u64::MAX - 1), 0, 99u32);
        q.schedule(SimTime::from_millis(1), 1, 1);
        assert_eq!(q.pop().map(|(_, v)| v), Some(1));
        // The remaining entry is far outside the current rotation.
        assert_eq!(q.peek_at(), Some(SimTime::from_nanos(u64::MAX - 1)));
        assert_eq!(q.pop().map(|(_, v)| v), Some(99));
        assert!(q.pop().is_none());
    }

    #[test]
    fn stats_track_rebuilds_scans_and_occupancy() {
        let mut q = CalendarQueue::new();
        assert_eq!(q.stats(), QueueStats::default());
        for i in 0..5_000u64 {
            q.schedule(SimTime::from_micros(i * 37 % 10_000), i, i as u32);
        }
        let after_fill = q.stats();
        assert!(after_fill.rebuilds > 0, "growth must rebuild");
        assert!(after_fill.max_bucket > 0);
        drain(&mut q);
        let after_drain = q.stats();
        assert!(after_drain.entry_scans > 0, "pops must scan entries");
        assert!(after_drain.rebuilds >= after_fill.rebuilds);
    }

    #[test]
    fn peek_matches_pop() {
        let mut q = CalendarQueue::new();
        for i in 0..200u64 {
            q.schedule(SimTime::from_micros(i * 13 % 500), i, i as u32);
        }
        while let Some(at) = q.peek_at() {
            let (popped_at, _) = q.pop().unwrap();
            assert_eq!(at, popped_at);
        }
        assert_eq!(q.len(), 0);
    }
}
