//! Virtual time: nanosecond-resolution monotonic simulation timestamps.

use std::fmt;
use std::ops::{Add, AddAssign, Sub};

/// A point in virtual time, in nanoseconds since simulation start.
///
/// Nanosecond resolution keeps sub-microsecond control-plane costs (router
/// dispatch, queue-proxy hops) representable while `u64` still covers ~584
/// simulated years — far beyond any trace replay.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct SimTime(pub u64);

impl SimTime {
    pub const ZERO: SimTime = SimTime(0);
    /// Sentinel for "never".
    pub const NEVER: SimTime = SimTime(u64::MAX);

    pub fn from_nanos(ns: u64) -> SimTime {
        SimTime(ns)
    }

    pub fn from_micros(us: u64) -> SimTime {
        SimTime(us * 1_000)
    }

    pub fn from_millis(ms: u64) -> SimTime {
        SimTime(ms * 1_000_000)
    }

    pub fn from_secs(s: u64) -> SimTime {
        SimTime(s * 1_000_000_000)
    }

    /// Fractional milliseconds → SimTime (latency models are in f64 ms).
    /// Negative inputs clamp to zero.
    pub fn from_millis_f64(ms: f64) -> SimTime {
        SimTime((ms.max(0.0) * 1_000_000.0).round() as u64)
    }

    pub fn from_secs_f64(s: f64) -> SimTime {
        SimTime::from_millis_f64(s * 1e3)
    }

    pub fn as_nanos(self) -> u64 {
        self.0
    }

    pub fn as_micros_f64(self) -> f64 {
        self.0 as f64 / 1e3
    }

    pub fn as_millis_f64(self) -> f64 {
        self.0 as f64 / 1e6
    }

    pub fn as_secs_f64(self) -> f64 {
        self.0 as f64 / 1e9
    }

    pub fn saturating_sub(self, other: SimTime) -> SimTime {
        SimTime(self.0.saturating_sub(other.0))
    }

    pub fn min(self, other: SimTime) -> SimTime {
        SimTime(self.0.min(other.0))
    }

    pub fn max(self, other: SimTime) -> SimTime {
        SimTime(self.0.max(other.0))
    }
}

impl Add for SimTime {
    type Output = SimTime;
    fn add(self, rhs: SimTime) -> SimTime {
        SimTime(self.0 + rhs.0)
    }
}

impl AddAssign for SimTime {
    fn add_assign(&mut self, rhs: SimTime) {
        self.0 += rhs.0;
    }
}

impl Sub for SimTime {
    type Output = SimTime;
    fn sub(self, rhs: SimTime) -> SimTime {
        SimTime(self.0 - rhs.0)
    }
}

impl fmt::Display for SimTime {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let ns = self.0;
        if ns < 1_000 {
            write!(f, "{ns}ns")
        } else if ns < 1_000_000 {
            write!(f, "{:.2}µs", ns as f64 / 1e3)
        } else if ns < 1_000_000_000 {
            write!(f, "{:.2}ms", ns as f64 / 1e6)
        } else {
            write!(f, "{:.3}s", ns as f64 / 1e9)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conversions() {
        assert_eq!(SimTime::from_millis(5).as_nanos(), 5_000_000);
        assert_eq!(SimTime::from_secs(2).as_millis_f64(), 2000.0);
        assert_eq!(SimTime::from_millis_f64(56.44).as_millis_f64(), 56.44);
        assert_eq!(SimTime::from_micros(3).as_micros_f64(), 3.0);
        assert_eq!(SimTime::from_secs_f64(1.5).as_secs_f64(), 1.5);
    }

    #[test]
    fn arithmetic_and_ordering() {
        let a = SimTime::from_millis(10);
        let b = SimTime::from_millis(25);
        assert_eq!((a + b).as_millis_f64(), 35.0);
        assert_eq!((b - a).as_millis_f64(), 15.0);
        assert!(a < b);
        assert_eq!(a.max(b), b);
        assert_eq!(a.min(b), a);
        assert_eq!(a.saturating_sub(b), SimTime::ZERO);
    }

    #[test]
    fn negative_f64_clamps_to_zero() {
        assert_eq!(SimTime::from_millis_f64(-3.0), SimTime::ZERO);
    }

    #[test]
    fn display_units() {
        assert_eq!(SimTime(900).to_string(), "900ns");
        assert_eq!(SimTime::from_micros(12).to_string(), "12.00µs");
        assert_eq!(SimTime::from_millis(56).to_string(), "56.00ms");
        assert_eq!(SimTime::from_secs(3).to_string(), "3.000s");
    }
}
