//! The event queue engine.
//!
//! `Engine<W>` is generic over the world state `W` (the platform). Handlers
//! are `FnOnce(&mut W, &mut Engine<W>)` — they mutate the world and schedule
//! follow-up events. Ordering is deterministic: ties in virtual time break by
//! insertion sequence, so two runs with the same seed replay identically.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::util::nohash::IdHashSet;

use super::clock::SimTime;

/// Handle for cancelling a scheduled event.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct EventId(pub u64);

type Handler<W> = Box<dyn FnOnce(&mut W, &mut Engine<W>)>;

struct Entry<W> {
    at: SimTime,
    seq: u64,
    id: EventId,
    f: Handler<W>,
}

impl<W> PartialEq for Entry<W> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}

impl<W> Eq for Entry<W> {}

impl<W> PartialOrd for Entry<W> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<W> Ord for Entry<W> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first.
        other
            .at
            .cmp(&self.at)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Result of a scheduling call.
#[derive(Debug, Clone, Copy)]
pub struct Scheduled {
    pub id: EventId,
    pub at: SimTime,
}

/// Discrete-event engine over world state `W`.
pub struct Engine<W> {
    now: SimTime,
    queue: BinaryHeap<Entry<W>>,
    next_seq: u64,
    cancelled: IdHashSet<EventId>,
    processed: u64,
}

impl<W> Default for Engine<W> {
    fn default() -> Self {
        Engine::new()
    }
}

impl<W> Engine<W> {
    pub fn new() -> Engine<W> {
        Engine {
            now: SimTime::ZERO,
            queue: BinaryHeap::new(),
            next_seq: 0,
            cancelled: IdHashSet::default(),
            processed: 0,
        }
    }

    /// Current virtual time.
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Total handlers executed so far (engine throughput metric).
    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Pending (non-cancelled) events.
    pub fn pending(&self) -> usize {
        self.queue.len() - self.cancelled.len().min(self.queue.len())
    }

    /// Schedules `f` at absolute time `at` (clamped to now if in the past).
    pub fn schedule_at<F>(&mut self, at: SimTime, f: F) -> Scheduled
    where
        F: FnOnce(&mut W, &mut Engine<W>) + 'static,
    {
        let at = at.max(self.now);
        let seq = self.next_seq;
        self.next_seq += 1;
        let id = EventId(seq);
        self.queue.push(Entry {
            at,
            seq,
            id,
            f: Box::new(f),
        });
        Scheduled { id, at }
    }

    /// Schedules `f` after virtual delay `d`.
    pub fn schedule_in<F>(&mut self, d: SimTime, f: F) -> Scheduled
    where
        F: FnOnce(&mut W, &mut Engine<W>) + 'static,
    {
        self.schedule_at(self.now + d, f)
    }

    /// Cancels a scheduled event. Safe to call on already-fired ids.
    pub fn cancel(&mut self, id: EventId) {
        self.cancelled.insert(id);
    }

    fn pop_next(&mut self) -> Option<Entry<W>> {
        while let Some(e) = self.queue.pop() {
            if self.cancelled.remove(&e.id) {
                continue;
            }
            return Some(e);
        }
        None
    }

    /// Runs until the queue drains. Returns events processed.
    pub fn run(&mut self, world: &mut W) -> u64 {
        let before = self.processed;
        while let Some(e) = self.pop_next() {
            debug_assert!(e.at >= self.now, "time went backwards");
            self.now = e.at;
            self.processed += 1;
            (e.f)(world, self);
        }
        self.processed - before
    }

    /// Runs events with `at <= deadline`, then advances the clock to
    /// `deadline`. Returns events processed.
    pub fn run_until(&mut self, world: &mut W, deadline: SimTime) -> u64 {
        let before = self.processed;
        loop {
            let next_at = loop {
                match self.queue.peek() {
                    Some(e) if self.cancelled.contains(&e.id) => {
                        let e = self.queue.pop().unwrap();
                        self.cancelled.remove(&e.id);
                    }
                    Some(e) => break Some(e.at),
                    None => break None,
                }
            };
            match next_at {
                Some(at) if at <= deadline => {
                    let e = self.pop_next().unwrap();
                    self.now = e.at;
                    self.processed += 1;
                    (e.f)(world, self);
                }
                _ => break,
            }
        }
        self.now = self.now.max(deadline);
        self.processed - before
    }

    /// Runs a single event if one is pending. Returns its time.
    pub fn step(&mut self, world: &mut W) -> Option<SimTime> {
        let e = self.pop_next()?;
        self.now = e.at;
        self.processed += 1;
        (e.f)(world, self);
        Some(self.now)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Default)]
    struct World {
        log: Vec<(u64, &'static str)>,
    }

    #[test]
    fn events_fire_in_time_order() {
        let mut eng: Engine<World> = Engine::new();
        let mut w = World::default();
        eng.schedule_at(SimTime::from_millis(30), |w: &mut World, _| {
            w.log.push((30, "c"))
        });
        eng.schedule_at(SimTime::from_millis(10), |w: &mut World, _| {
            w.log.push((10, "a"))
        });
        eng.schedule_at(SimTime::from_millis(20), |w: &mut World, _| {
            w.log.push((20, "b"))
        });
        let n = eng.run(&mut w);
        assert_eq!(n, 3);
        assert_eq!(w.log, vec![(10, "a"), (20, "b"), (30, "c")]);
        assert_eq!(eng.now(), SimTime::from_millis(30));
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut eng: Engine<World> = Engine::new();
        let mut w = World::default();
        let t = SimTime::from_millis(5);
        eng.schedule_at(t, |w: &mut World, _| w.log.push((5, "first")));
        eng.schedule_at(t, |w: &mut World, _| w.log.push((5, "second")));
        eng.run(&mut w);
        assert_eq!(w.log, vec![(5, "first"), (5, "second")]);
    }

    #[test]
    fn handlers_can_schedule_follow_ups() {
        let mut eng: Engine<World> = Engine::new();
        let mut w = World::default();
        eng.schedule_at(SimTime::from_millis(1), |w: &mut World, eng| {
            w.log.push((1, "start"));
            eng.schedule_in(SimTime::from_millis(9), |w: &mut World, _| {
                w.log.push((10, "chained"));
            });
        });
        eng.run(&mut w);
        assert_eq!(w.log, vec![(1, "start"), (10, "chained")]);
        assert_eq!(eng.now(), SimTime::from_millis(10));
    }

    #[test]
    fn cancel_prevents_execution() {
        let mut eng: Engine<World> = Engine::new();
        let mut w = World::default();
        let s = eng.schedule_at(SimTime::from_millis(10), |w: &mut World, _| {
            w.log.push((10, "cancelled"))
        });
        eng.schedule_at(SimTime::from_millis(20), |w: &mut World, _| {
            w.log.push((20, "kept"))
        });
        eng.cancel(s.id);
        eng.run(&mut w);
        assert_eq!(w.log, vec![(20, "kept")]);
    }

    #[test]
    fn run_until_stops_and_advances_clock() {
        let mut eng: Engine<World> = Engine::new();
        let mut w = World::default();
        eng.schedule_at(SimTime::from_millis(10), |w: &mut World, _| {
            w.log.push((10, "in"))
        });
        eng.schedule_at(SimTime::from_millis(100), |w: &mut World, _| {
            w.log.push((100, "out"))
        });
        let n = eng.run_until(&mut w, SimTime::from_millis(50));
        assert_eq!(n, 1);
        assert_eq!(w.log, vec![(10, "in")]);
        assert_eq!(eng.now(), SimTime::from_millis(50));
        eng.run(&mut w);
        assert_eq!(w.log.len(), 2);
    }

    #[test]
    fn past_schedules_clamp_to_now() {
        let mut eng: Engine<World> = Engine::new();
        let mut w = World::default();
        eng.schedule_at(SimTime::from_millis(10), |w: &mut World, eng| {
            // Try to schedule in the past — must fire at `now`, not panic.
            eng.schedule_at(SimTime::from_millis(1), |w: &mut World, _| {
                w.log.push((10, "clamped"))
            });
            w.log.push((10, "origin"));
        });
        eng.run(&mut w);
        assert_eq!(w.log, vec![(10, "origin"), (10, "clamped")]);
    }

    #[test]
    fn step_processes_one_event() {
        let mut eng: Engine<World> = Engine::new();
        let mut w = World::default();
        eng.schedule_at(SimTime::from_millis(1), |w: &mut World, _| {
            w.log.push((1, "one"))
        });
        eng.schedule_at(SimTime::from_millis(2), |w: &mut World, _| {
            w.log.push((2, "two"))
        });
        assert_eq!(eng.step(&mut w), Some(SimTime::from_millis(1)));
        assert_eq!(w.log.len(), 1);
        assert_eq!(eng.pending(), 1);
    }

    #[test]
    fn deterministic_processed_count() {
        let run = || {
            let mut eng: Engine<World> = Engine::new();
            let mut w = World::default();
            for i in 0..100u64 {
                eng.schedule_at(SimTime::from_micros(i * 7 % 50), move |w: &mut World, _| {
                    w.log.push((i, "x"))
                });
            }
            eng.run(&mut w);
            w.log
        };
        assert_eq!(run(), run());
    }
}
