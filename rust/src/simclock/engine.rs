//! The event queue engine.
//!
//! `Engine<W>` is generic over the world state `W` (the platform). The
//! world declares a typed event alphabet via [`World`] and dispatches each
//! popped event itself — one `match` per event, zero per-event heap
//! allocation in the steady-state loop. Events live in a
//! [`CalendarQueue`](super::CalendarQueue) (O(1) amortized schedule/pop)
//! with slot-based generation-stamped cancellation, so `pending()` is exact
//! and cancelling an already-fired id is a true no-op rather than a leaked
//! tombstone. Ordering is deterministic: ties in virtual time break by
//! insertion sequence, so two runs with the same seed replay identically —
//! bit-for-bit the same order as the retained BinaryHeap reference in
//! [`oracle`](super::oracle) (pinned by `tests/engine_diff.rs`).

use super::calendar::{CalendarQueue, QueueStats};
use super::clock::SimTime;

/// World state driven by an [`Engine`]: declares the event alphabet and
/// dispatches each fired event (typically one `match` over `Self::Event`).
pub trait World: Sized {
    type Event;

    fn handle(&mut self, ev: Self::Event, eng: &mut Engine<Self>);
}

/// Handle for cancelling a scheduled event.
///
/// Packs the calendar-queue slot (low 32 bits) and its generation stamp
/// (high 32 bits): slots are recycled across events, generations make stale
/// handles inert.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct EventId(pub u64);

impl EventId {
    fn pack(slot: u32, generation: u32) -> EventId {
        EventId(((generation as u64) << 32) | slot as u64)
    }

    fn slot_index(self) -> u32 {
        self.0 as u32
    }

    fn generation(self) -> u32 {
        (self.0 >> 32) as u32
    }
}

/// Result of a scheduling call.
#[derive(Debug, Clone, Copy)]
pub struct Scheduled {
    pub id: EventId,
    pub at: SimTime,
}

/// Discrete-event engine over world state `W`.
pub struct Engine<W: World> {
    now: SimTime,
    queue: CalendarQueue<W::Event>,
    next_seq: u64,
    processed: u64,
    last_processed_at: SimTime,
}

impl<W: World> Default for Engine<W> {
    fn default() -> Self {
        Engine::new()
    }
}

impl<W: World> Engine<W> {
    pub fn new() -> Engine<W> {
        Engine {
            now: SimTime::ZERO,
            queue: CalendarQueue::new(),
            next_seq: 0,
            processed: 0,
            last_processed_at: SimTime::ZERO,
        }
    }

    /// Current virtual time.
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Total events executed so far (engine throughput metric).
    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Virtual time of the most recently executed event. Unlike [`now`],
    /// this never advances on an eventless `run_until` — it is the
    /// window-partition-invariant end-of-run clock the sharded runtime
    /// merges metrics at (`Engine::now` lands on the final sync-window
    /// deadline instead, which depends on how the run was windowed).
    ///
    /// [`now`]: Engine::now
    pub fn last_processed_at(&self) -> SimTime {
        self.last_processed_at
    }

    /// Pending (non-cancelled) events — exact.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Calendar-queue activity counters (self-profiling plane).
    pub fn queue_stats(&self) -> QueueStats {
        self.queue.stats()
    }

    /// Virtual time of the earliest pending event, if any. Lets a windowed
    /// multi-engine driver skip dead windows without popping anything.
    pub fn next_at(&mut self) -> Option<SimTime> {
        self.queue.peek_at()
    }

    /// Schedules `ev` at absolute time `at` (clamped to now if in the past).
    pub fn schedule_at(&mut self, at: SimTime, ev: W::Event) -> Scheduled {
        let at = at.max(self.now);
        let seq = self.next_seq;
        self.next_seq += 1;
        let (slot, generation) = self.queue.schedule(at, seq, ev);
        Scheduled {
            id: EventId::pack(slot, generation),
            at,
        }
    }

    /// Schedules `ev` after virtual delay `d`.
    pub fn schedule_in(&mut self, d: SimTime, ev: W::Event) -> Scheduled {
        self.schedule_at(self.now + d, ev)
    }

    /// Cancels a scheduled event. A true no-op on already-fired, already-
    /// cancelled, or otherwise stale ids — no tombstone survives.
    pub fn cancel(&mut self, id: EventId) {
        self.queue.cancel(id.slot_index(), id.generation());
    }

    /// Runs until the queue drains. Returns events processed.
    pub fn run(&mut self, world: &mut W) -> u64 {
        let before = self.processed;
        while let Some((at, ev)) = self.queue.pop() {
            debug_assert!(at >= self.now, "time went backwards");
            self.now = at;
            self.last_processed_at = at;
            self.processed += 1;
            world.handle(ev, self);
        }
        self.processed - before
    }

    /// Runs events with `at <= deadline`, then advances the clock to
    /// `deadline`. Returns events processed.
    pub fn run_until(&mut self, world: &mut W, deadline: SimTime) -> u64 {
        let before = self.processed;
        while let Some(at) = self.queue.peek_at() {
            if at > deadline {
                break;
            }
            let (at, ev) = self.queue.pop().expect("peeked event vanished");
            self.now = at;
            self.last_processed_at = at;
            self.processed += 1;
            world.handle(ev, self);
        }
        self.now = self.now.max(deadline);
        self.processed - before
    }

    /// Runs a single event if one is pending. Returns its time.
    pub fn step(&mut self, world: &mut W) -> Option<SimTime> {
        let (at, ev) = self.queue.pop()?;
        self.now = at;
        self.last_processed_at = at;
        self.processed += 1;
        world.handle(ev, self);
        Some(self.now)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Default)]
    struct TestWorld {
        log: Vec<(u64, &'static str)>,
    }

    /// Typed test alphabet mirroring what the old closure tests expressed.
    enum Ev {
        Log(u64, &'static str),
        /// Log, then schedule a follow-up `delay` later.
        Chain {
            log: (u64, &'static str),
            delay: SimTime,
            then: (u64, &'static str),
        },
        /// Schedule a (possibly past) absolute-time follow-up, then log.
        ScheduleAt {
            log: (u64, &'static str),
            at: SimTime,
            then: (u64, &'static str),
        },
    }

    impl World for TestWorld {
        type Event = Ev;

        fn handle(&mut self, ev: Ev, eng: &mut Engine<Self>) {
            match ev {
                Ev::Log(t, s) => self.log.push((t, s)),
                Ev::Chain { log, delay, then } => {
                    self.log.push(log);
                    eng.schedule_in(delay, Ev::Log(then.0, then.1));
                }
                Ev::ScheduleAt { log, at, then } => {
                    eng.schedule_at(at, Ev::Log(then.0, then.1));
                    self.log.push(log);
                }
            }
        }
    }

    #[test]
    fn events_fire_in_time_order() {
        let mut eng: Engine<TestWorld> = Engine::new();
        let mut w = TestWorld::default();
        eng.schedule_at(SimTime::from_millis(30), Ev::Log(30, "c"));
        eng.schedule_at(SimTime::from_millis(10), Ev::Log(10, "a"));
        eng.schedule_at(SimTime::from_millis(20), Ev::Log(20, "b"));
        let n = eng.run(&mut w);
        assert_eq!(n, 3);
        assert_eq!(w.log, vec![(10, "a"), (20, "b"), (30, "c")]);
        assert_eq!(eng.now(), SimTime::from_millis(30));
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut eng: Engine<TestWorld> = Engine::new();
        let mut w = TestWorld::default();
        let t = SimTime::from_millis(5);
        eng.schedule_at(t, Ev::Log(5, "first"));
        eng.schedule_at(t, Ev::Log(5, "second"));
        eng.run(&mut w);
        assert_eq!(w.log, vec![(5, "first"), (5, "second")]);
    }

    #[test]
    fn handlers_can_schedule_follow_ups() {
        let mut eng: Engine<TestWorld> = Engine::new();
        let mut w = TestWorld::default();
        eng.schedule_at(
            SimTime::from_millis(1),
            Ev::Chain {
                log: (1, "start"),
                delay: SimTime::from_millis(9),
                then: (10, "chained"),
            },
        );
        eng.run(&mut w);
        assert_eq!(w.log, vec![(1, "start"), (10, "chained")]);
        assert_eq!(eng.now(), SimTime::from_millis(10));
    }

    #[test]
    fn cancel_prevents_execution() {
        let mut eng: Engine<TestWorld> = Engine::new();
        let mut w = TestWorld::default();
        let s = eng.schedule_at(SimTime::from_millis(10), Ev::Log(10, "cancelled"));
        eng.schedule_at(SimTime::from_millis(20), Ev::Log(20, "kept"));
        eng.cancel(s.id);
        eng.run(&mut w);
        assert_eq!(w.log, vec![(20, "kept")]);
    }

    #[test]
    fn run_until_stops_and_advances_clock() {
        let mut eng: Engine<TestWorld> = Engine::new();
        let mut w = TestWorld::default();
        eng.schedule_at(SimTime::from_millis(10), Ev::Log(10, "in"));
        eng.schedule_at(SimTime::from_millis(100), Ev::Log(100, "out"));
        let n = eng.run_until(&mut w, SimTime::from_millis(50));
        assert_eq!(n, 1);
        assert_eq!(w.log, vec![(10, "in")]);
        assert_eq!(eng.now(), SimTime::from_millis(50));
        eng.run(&mut w);
        assert_eq!(w.log.len(), 2);
    }

    #[test]
    fn past_schedules_clamp_to_now() {
        let mut eng: Engine<TestWorld> = Engine::new();
        let mut w = TestWorld::default();
        eng.schedule_at(
            SimTime::from_millis(10),
            Ev::ScheduleAt {
                log: (10, "origin"),
                // In the past at fire time — must clamp to `now`, not panic.
                at: SimTime::from_millis(1),
                then: (10, "clamped"),
            },
        );
        eng.run(&mut w);
        assert_eq!(w.log, vec![(10, "origin"), (10, "clamped")]);
    }

    #[test]
    fn step_processes_one_event() {
        let mut eng: Engine<TestWorld> = Engine::new();
        let mut w = TestWorld::default();
        eng.schedule_at(SimTime::from_millis(1), Ev::Log(1, "one"));
        eng.schedule_at(SimTime::from_millis(2), Ev::Log(2, "two"));
        assert_eq!(eng.step(&mut w), Some(SimTime::from_millis(1)));
        assert_eq!(w.log.len(), 1);
        assert_eq!(eng.pending(), 1);
    }

    #[test]
    fn deterministic_processed_count() {
        let run = || {
            let mut eng: Engine<TestWorld> = Engine::new();
            let mut w = TestWorld::default();
            for i in 0..100u64 {
                eng.schedule_at(SimTime::from_micros(i * 7 % 50), Ev::Log(i, "x"));
            }
            eng.run(&mut w);
            w.log
        };
        assert_eq!(run(), run());
    }

    /// Regression for the old tombstone leak: cancelling an id that already
    /// fired must not skew `pending()` — it is exact under the slot design.
    #[test]
    fn pending_is_exact_after_cancelling_fired_ids() {
        let mut eng: Engine<TestWorld> = Engine::new();
        let mut w = TestWorld::default();
        let s = eng.schedule_at(SimTime::from_millis(1), Ev::Log(1, "fired"));
        eng.run(&mut w);
        assert_eq!(w.log.len(), 1);
        eng.cancel(s.id); // no-op: already fired
        let kept = eng.schedule_at(SimTime::from_millis(2), Ev::Log(2, "pending"));
        assert_eq!(eng.pending(), 1, "stale cancel must not be subtracted");
        eng.cancel(kept.id);
        assert_eq!(eng.pending(), 0);
        assert_eq!(eng.run(&mut w), 0);
        assert_eq!(w.log.len(), 1);
    }

    #[test]
    fn next_at_peeks_without_popping() {
        let mut eng: Engine<TestWorld> = Engine::new();
        let mut w = TestWorld::default();
        assert_eq!(eng.next_at(), None);
        eng.schedule_at(SimTime::from_millis(20), Ev::Log(20, "later"));
        eng.schedule_at(SimTime::from_millis(10), Ev::Log(10, "sooner"));
        assert_eq!(eng.next_at(), Some(SimTime::from_millis(10)));
        assert_eq!(eng.pending(), 2, "peek must not consume");
        eng.run(&mut w);
        assert_eq!(eng.next_at(), None);
    }

    /// A stale id whose slot was recycled must not cancel the new tenant.
    #[test]
    fn stale_cancel_does_not_kill_reused_slot() {
        let mut eng: Engine<TestWorld> = Engine::new();
        let mut w = TestWorld::default();
        let old = eng.schedule_at(SimTime::from_millis(1), Ev::Log(1, "a"));
        eng.run(&mut w);
        let newer = eng.schedule_at(SimTime::from_millis(2), Ev::Log(2, "b"));
        // Same physical slot, different generation.
        assert_ne!(old.id, newer.id);
        eng.cancel(old.id);
        eng.run(&mut w);
        assert_eq!(w.log, vec![(1, "a"), (2, "b")]);
    }
}
