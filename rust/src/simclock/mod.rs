//! Discrete-event simulation core.
//!
//! Everything time-dependent in the platform — pod lifecycle transitions,
//! cgroup reconfiguration latencies, request service under CFS shares,
//! autoscaler ticks, load-generator arrivals — runs on a virtual clock so
//! a "10-minute video" workload (Table 2's 119 s runtime) simulates in
//! microseconds and experiments are exactly reproducible.

mod clock;
mod engine;

pub use clock::SimTime;
pub use engine::{Engine, EventId, Scheduled};
