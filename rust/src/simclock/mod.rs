//! Discrete-event simulation core.
//!
//! Everything time-dependent in the platform — pod lifecycle transitions,
//! cgroup reconfiguration latencies, request service under CFS shares,
//! autoscaler ticks, load-generator arrivals — runs on a virtual clock so
//! a "10-minute video" workload (Table 2's 119 s runtime) simulates in
//! microseconds and experiments are exactly reproducible.
//!
//! The hot path is a typed-event engine: worlds implement [`World`] with an
//! event enum, and [`Engine`] pops from a [`CalendarQueue`] (O(1) amortized)
//! with generation-stamped cancellation. The original boxed-closure
//! BinaryHeap engine survives in [`oracle`] as the differential-test
//! reference for event ordering.

mod calendar;
mod clock;
mod engine;
pub mod oracle;

pub use calendar::{CalendarQueue, QueueStats};
pub use clock::SimTime;
pub use engine::{Engine, EventId, Scheduled, World};
