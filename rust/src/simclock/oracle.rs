//! The original boxed-closure BinaryHeap engine, retained verbatim as the
//! *oracle* for differential testing of the calendar-queue core.
//!
//! This is the pre-rebuild `Engine` — `Box<dyn FnOnce>` handlers, a
//! `BinaryHeap` with inverted `Ord`, and tombstone-set cancellation —
//! including its known warts: `cancel` on an already-fired id leaks a
//! tombstone forever (skewing `pending()`), and `run_until` carries its own
//! copy of the cancelled-entry drain loop. **Do not fix anything here.**
//! Its observable event order is the specification the new engine must
//! reproduce bit-for-bit; `tests/engine_diff.rs` replays randomized
//! schedules with cancellations against both and asserts identical firing
//! order and `processed` counts.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::util::nohash::IdHashSet;

use super::clock::SimTime;

/// Handle for cancelling a scheduled event.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct OracleEventId(pub u64);

type Handler<W> = Box<dyn FnOnce(&mut W, &mut OracleEngine<W>)>;

struct Entry<W> {
    at: SimTime,
    seq: u64,
    id: OracleEventId,
    f: Handler<W>,
}

impl<W> PartialEq for Entry<W> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}

impl<W> Eq for Entry<W> {}

impl<W> PartialOrd for Entry<W> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<W> Ord for Entry<W> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first.
        other
            .at
            .cmp(&self.at)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Result of a scheduling call.
#[derive(Debug, Clone, Copy)]
pub struct OracleScheduled {
    pub id: OracleEventId,
    pub at: SimTime,
}

/// Discrete-event engine over world state `W` — the reference
/// implementation.
pub struct OracleEngine<W> {
    now: SimTime,
    queue: BinaryHeap<Entry<W>>,
    next_seq: u64,
    cancelled: IdHashSet<OracleEventId>,
    processed: u64,
}

impl<W> Default for OracleEngine<W> {
    fn default() -> Self {
        OracleEngine::new()
    }
}

impl<W> OracleEngine<W> {
    pub fn new() -> OracleEngine<W> {
        OracleEngine {
            now: SimTime::ZERO,
            queue: BinaryHeap::new(),
            next_seq: 0,
            cancelled: IdHashSet::default(),
            processed: 0,
        }
    }

    /// Current virtual time.
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Total handlers executed so far (engine throughput metric).
    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Pending events — *approximate*: tombstones for already-fired ids are
    /// subtracted forever (the leak the calendar-queue engine fixes).
    pub fn pending(&self) -> usize {
        self.queue.len() - self.cancelled.len().min(self.queue.len())
    }

    /// Schedules `f` at absolute time `at` (clamped to now if in the past).
    pub fn schedule_at<F>(&mut self, at: SimTime, f: F) -> OracleScheduled
    where
        F: FnOnce(&mut W, &mut OracleEngine<W>) + 'static,
    {
        let at = at.max(self.now);
        let seq = self.next_seq;
        self.next_seq += 1;
        let id = OracleEventId(seq);
        self.queue.push(Entry {
            at,
            seq,
            id,
            f: Box::new(f),
        });
        OracleScheduled { id, at }
    }

    /// Schedules `f` after virtual delay `d`.
    pub fn schedule_in<F>(&mut self, d: SimTime, f: F) -> OracleScheduled
    where
        F: FnOnce(&mut W, &mut OracleEngine<W>) + 'static,
    {
        self.schedule_at(self.now + d, f)
    }

    /// Cancels a scheduled event. Safe to call on already-fired ids (but
    /// leaks a tombstone — see the module docs).
    pub fn cancel(&mut self, id: OracleEventId) {
        self.cancelled.insert(id);
    }

    fn pop_next(&mut self) -> Option<Entry<W>> {
        while let Some(e) = self.queue.pop() {
            if self.cancelled.remove(&e.id) {
                continue;
            }
            return Some(e);
        }
        None
    }

    /// Runs until the queue drains. Returns events processed.
    pub fn run(&mut self, world: &mut W) -> u64 {
        let before = self.processed;
        while let Some(e) = self.pop_next() {
            debug_assert!(e.at >= self.now, "time went backwards");
            self.now = e.at;
            self.processed += 1;
            (e.f)(world, self);
        }
        self.processed - before
    }

    /// Runs events with `at <= deadline`, then advances the clock to
    /// `deadline`. Returns events processed.
    pub fn run_until(&mut self, world: &mut W, deadline: SimTime) -> u64 {
        let before = self.processed;
        loop {
            let next_at = loop {
                match self.queue.peek() {
                    Some(e) if self.cancelled.contains(&e.id) => {
                        let e = self.queue.pop().unwrap();
                        self.cancelled.remove(&e.id);
                    }
                    Some(e) => break Some(e.at),
                    None => break None,
                }
            };
            match next_at {
                Some(at) if at <= deadline => {
                    let e = self.pop_next().unwrap();
                    self.now = e.at;
                    self.processed += 1;
                    (e.f)(world, self);
                }
                _ => break,
            }
        }
        self.now = self.now.max(deadline);
        self.processed - before
    }

    /// Runs a single event if one is pending. Returns its time.
    pub fn step(&mut self, world: &mut W) -> Option<SimTime> {
        let e = self.pop_next()?;
        self.now = e.at;
        self.processed += 1;
        (e.f)(world, self);
        Some(self.now)
    }
}
