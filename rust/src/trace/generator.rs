//! Synthetic FaaS trace generator (Shahrad-style).

use crate::simclock::SimTime;
use crate::util::rng::Rng;
use crate::workload::registry::{WorkloadKind, WorkloadProfile};

/// One invocation in a trace.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    pub at: SimTime,
    /// Index into the function population.
    pub function: usize,
}

/// Shape of the time-varying aggregate rate — the adversarial workload
/// knob for fault scenarios (a flash crowd landing inside a straggler
/// window, an ON-OFF square wave fighting the scale-down grace period).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum RatePattern {
    /// Sinusoid between trough and peak (the Shahrad-style scaled "day").
    #[default]
    Diurnal,
    /// The diurnal base with a Gaussian flash-crowd spike centered at
    /// `at`: the rate multiplies by up to `magnitude`, decaying with a
    /// standard deviation of `width`.
    FlashCrowd {
        at: SimTime,
        magnitude: f64,
        width: SimTime,
    },
    /// A square wave: `peak_rate` for `on`, the trough rate for `off`,
    /// repeating — maximal churn pressure on scale-to-zero policies.
    OnOff { on: SimTime, off: SimTime },
}

/// Trace generation parameters.
#[derive(Debug, Clone)]
pub struct TraceConfig {
    /// Number of distinct functions.
    pub functions: usize,
    /// Zipf exponent for per-function popularity (≈1.1–1.5 in production).
    pub popularity_s: f64,
    /// Mean aggregate invocation rate (req/s) at the diurnal peak.
    pub peak_rate: f64,
    /// Ratio of trough to peak rate (diurnal depth), in (0, 1].
    pub trough_ratio: f64,
    /// Diurnal period (a scaled-down "day").
    pub period: SimTime,
    /// Trace horizon.
    pub horizon: SimTime,
    /// Burstiness: probability an arrival spawns an immediate follow-up.
    pub burst_p: f64,
    /// Shape of the aggregate rate over time (default: diurnal sinusoid,
    /// which reproduces pre-pattern traces bit-for-bit).
    pub pattern: RatePattern,
    pub seed: u64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            functions: 12,
            popularity_s: 1.2,
            peak_rate: 6.0,
            trough_ratio: 0.15,
            period: SimTime::from_secs(600),
            horizon: SimTime::from_secs(1200),
            burst_p: 0.25,
            pattern: RatePattern::Diurnal,
            seed: 1,
        }
    }
}

/// Generates traces from a config.
pub struct TraceGenerator {
    cfg: TraceConfig,
}

impl TraceGenerator {
    pub fn new(cfg: TraceConfig) -> TraceGenerator {
        TraceGenerator { cfg }
    }

    /// The diurnal sinusoid between trough and peak — the base every
    /// pattern modulates.
    fn diurnal_at(&self, t: SimTime) -> f64 {
        let phase = 2.0 * std::f64::consts::PI * t.as_secs_f64()
            / self.cfg.period.as_secs_f64().max(1e-9);
        let lo = self.cfg.peak_rate * self.cfg.trough_ratio;
        let hi = self.cfg.peak_rate;
        lo + (hi - lo) * 0.5 * (1.0 - phase.cos())
    }

    /// Instantaneous aggregate rate at time `t` under the configured
    /// [`RatePattern`].
    pub fn rate_at(&self, t: SimTime) -> f64 {
        match self.cfg.pattern {
            RatePattern::Diurnal => self.diurnal_at(t),
            RatePattern::FlashCrowd { at, magnitude, width } => {
                let d = (t.as_secs_f64() - at.as_secs_f64())
                    / width.as_secs_f64().max(1e-9);
                self.diurnal_at(t) * (1.0 + (magnitude - 1.0) * (-0.5 * d * d).exp())
            }
            RatePattern::OnOff { on, off } => {
                let period = (on + off).as_secs_f64().max(1e-9);
                let phase = t.as_secs_f64() % period;
                if phase < on.as_secs_f64() {
                    self.cfg.peak_rate
                } else {
                    self.cfg.peak_rate * self.cfg.trough_ratio
                }
            }
        }
    }

    /// The thinning envelope: an upper bound on [`TraceGenerator::rate_at`]
    /// over the whole horizon. Diurnal and ON-OFF peak at `peak_rate`; a
    /// flash crowd exceeds it by its magnitude, so the envelope must grow
    /// with it or the spike would be silently clipped.
    pub fn max_rate(&self) -> f64 {
        match self.cfg.pattern {
            RatePattern::FlashCrowd { magnitude, .. } => {
                self.cfg.peak_rate * magnitude.max(1.0)
            }
            _ => self.cfg.peak_rate,
        }
    }

    /// Generates the trace: thinned (time-varying) Poisson arrivals with
    /// Zipf function assignment and optional burst doubling.
    pub fn generate(&self) -> Vec<TraceEvent> {
        let mut rng = Rng::new(self.cfg.seed);
        let mut out = Vec::new();
        let horizon_s = self.cfg.horizon.as_secs_f64();
        let peak = self.max_rate().max(1e-9);
        let mut t = 0.0f64;
        loop {
            // Thinning: candidate arrivals at the peak rate, accepted with
            // probability rate(t)/peak.
            t += rng.exponential(peak);
            if t >= horizon_s {
                break;
            }
            let at = SimTime::from_secs_f64(t);
            if !rng.chance(self.rate_at(at) / peak) {
                continue;
            }
            let function = rng.zipf(self.cfg.functions, self.cfg.popularity_s);
            out.push(TraceEvent { at, function });
            // Bursts: correlated immediate retries/fan-outs.
            if rng.chance(self.cfg.burst_p) {
                let burst = 1 + rng.below(3);
                for i in 0..burst {
                    out.push(TraceEvent {
                        at: at + SimTime::from_millis(1 + i),
                        function,
                    });
                }
            }
        }
        out.sort_by_key(|e| e.at);
        out
    }

    /// Maps function indices onto paper workloads: hot ranks get the short
    /// functions (matching Shahrad's "most invocations are short"); the
    /// heavy video job appears only in the cold tail so aggregate demand
    /// stays within a single-node testbed.
    pub fn profile_for(rank: usize) -> WorkloadProfile {
        let kind = match rank % 8 {
            0 | 1 | 2 => WorkloadKind::HelloWorld,
            3 | 4 => WorkloadKind::Io,
            5 => WorkloadKind::Cpu,
            6 => WorkloadKind::Video10s,
            _ => WorkloadKind::Video1m,
        };
        WorkloadProfile::paper(kind)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> TraceConfig {
        TraceConfig {
            functions: 8,
            horizon: SimTime::from_secs(300),
            ..TraceConfig::default()
        }
    }

    #[test]
    fn trace_sorted_and_in_horizon() {
        let trace = TraceGenerator::new(small()).generate();
        assert!(!trace.is_empty());
        assert!(trace.windows(2).all(|w| w[0].at <= w[1].at));
        assert!(trace.iter().all(|e| e.at < SimTime::from_secs(302)));
        assert!(trace.iter().all(|e| e.function < 8));
    }

    #[test]
    fn popularity_is_skewed() {
        let trace = TraceGenerator::new(TraceConfig {
            horizon: SimTime::from_secs(2000),
            ..small()
        })
        .generate();
        let mut counts = vec![0usize; 8];
        for e in &trace {
            counts[e.function] += 1;
        }
        // Rank 0 should dominate rank 7 heavily.
        assert!(counts[0] > 4 * counts[7].max(1), "{counts:?}");
    }

    #[test]
    fn diurnal_rate_varies() {
        let g = TraceGenerator::new(small());
        let trough = g.rate_at(SimTime::ZERO);
        let peak = g.rate_at(SimTime::from_secs(300)); // half period
        assert!(peak > 3.0 * trough, "trough={trough} peak={peak}");
    }

    #[test]
    fn deterministic_per_seed() {
        let a = TraceGenerator::new(small()).generate();
        let b = TraceGenerator::new(small()).generate();
        assert_eq!(a, b);
        let c = TraceGenerator::new(TraceConfig {
            seed: 9,
            ..small()
        })
        .generate();
        assert_ne!(a, c);
    }

    #[test]
    fn flash_crowd_spikes_rate_and_arrivals() {
        let at = SimTime::from_secs(150);
        let g = TraceGenerator::new(TraceConfig {
            pattern: RatePattern::FlashCrowd {
                at,
                magnitude: 6.0,
                width: SimTime::from_secs(10),
            },
            ..small()
        });
        let base = TraceGenerator::new(small());
        // At the spike center the rate is magnified...
        assert!(g.rate_at(at) > 4.0 * base.rate_at(at));
        // ...and stays under the thinning envelope everywhere, so the
        // acceptance probability is a real probability.
        for s in 0..300 {
            let t = SimTime::from_secs(s);
            assert!(
                g.rate_at(t) <= g.max_rate() + 1e-9,
                "rate at {s}s exceeds the envelope"
            );
        }
        // The generated trace densifies around the spike.
        let trace = g.generate();
        let window = |lo: u64, hi: u64| {
            trace
                .iter()
                .filter(|e| {
                    e.at >= SimTime::from_secs(lo) && e.at < SimTime::from_secs(hi)
                })
                .count()
        };
        assert!(
            window(140, 160) > 2 * window(40, 60),
            "spike window {} vs quiet window {}",
            window(140, 160),
            window(40, 60)
        );
    }

    #[test]
    fn on_off_square_wave_alternates_between_peak_and_trough() {
        let cfg = TraceConfig {
            pattern: RatePattern::OnOff {
                on: SimTime::from_secs(30),
                off: SimTime::from_secs(30),
            },
            ..small()
        };
        let g = TraceGenerator::new(cfg.clone());
        assert_eq!(g.rate_at(SimTime::from_secs(10)), cfg.peak_rate);
        assert_eq!(
            g.rate_at(SimTime::from_secs(40)),
            cfg.peak_rate * cfg.trough_ratio
        );
        // Next period: on again.
        assert_eq!(g.rate_at(SimTime::from_secs(70)), cfg.peak_rate);
        assert_eq!(g.max_rate(), cfg.peak_rate);
    }

    /// The default pattern is the pre-pattern diurnal path: the thinning
    /// envelope is unchanged, so existing seeds reproduce bit-for-bit.
    #[test]
    fn diurnal_default_keeps_the_envelope() {
        let g = TraceGenerator::new(small());
        assert_eq!(g.max_rate(), small().peak_rate);
        assert_eq!(small().pattern, RatePattern::Diurnal);
    }

    #[test]
    fn bursts_produce_near_simultaneous_arrivals() {
        let trace = TraceGenerator::new(TraceConfig {
            burst_p: 1.0,
            ..small()
        })
        .generate();
        let mut bursty = 0;
        for w in trace.windows(2) {
            if (w[1].at - w[0].at).as_millis_f64() <= 3.0 && w[0].function == w[1].function {
                bursty += 1;
            }
        }
        assert!(bursty > trace.len() / 4, "bursty={bursty}/{}", trace.len());
    }
}
