//! Synthetic FaaS trace generator (Shahrad-style).

use crate::simclock::SimTime;
use crate::util::rng::Rng;
use crate::workload::registry::{WorkloadKind, WorkloadProfile};

/// One invocation in a trace.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    pub at: SimTime,
    /// Index into the function population.
    pub function: usize,
}

/// Trace generation parameters.
#[derive(Debug, Clone)]
pub struct TraceConfig {
    /// Number of distinct functions.
    pub functions: usize,
    /// Zipf exponent for per-function popularity (≈1.1–1.5 in production).
    pub popularity_s: f64,
    /// Mean aggregate invocation rate (req/s) at the diurnal peak.
    pub peak_rate: f64,
    /// Ratio of trough to peak rate (diurnal depth), in (0, 1].
    pub trough_ratio: f64,
    /// Diurnal period (a scaled-down "day").
    pub period: SimTime,
    /// Trace horizon.
    pub horizon: SimTime,
    /// Burstiness: probability an arrival spawns an immediate follow-up.
    pub burst_p: f64,
    pub seed: u64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            functions: 12,
            popularity_s: 1.2,
            peak_rate: 6.0,
            trough_ratio: 0.15,
            period: SimTime::from_secs(600),
            horizon: SimTime::from_secs(1200),
            burst_p: 0.25,
            seed: 1,
        }
    }
}

/// Generates traces from a config.
pub struct TraceGenerator {
    cfg: TraceConfig,
}

impl TraceGenerator {
    pub fn new(cfg: TraceConfig) -> TraceGenerator {
        TraceGenerator { cfg }
    }

    /// Diurnal rate at time `t` (sinusoid between trough and peak).
    pub fn rate_at(&self, t: SimTime) -> f64 {
        let phase = 2.0 * std::f64::consts::PI * t.as_secs_f64()
            / self.cfg.period.as_secs_f64().max(1e-9);
        let lo = self.cfg.peak_rate * self.cfg.trough_ratio;
        let hi = self.cfg.peak_rate;
        lo + (hi - lo) * 0.5 * (1.0 - phase.cos())
    }

    /// Generates the trace: thinned (time-varying) Poisson arrivals with
    /// Zipf function assignment and optional burst doubling.
    pub fn generate(&self) -> Vec<TraceEvent> {
        let mut rng = Rng::new(self.cfg.seed);
        let mut out = Vec::new();
        let horizon_s = self.cfg.horizon.as_secs_f64();
        let peak = self.cfg.peak_rate.max(1e-9);
        let mut t = 0.0f64;
        loop {
            // Thinning: candidate arrivals at the peak rate, accepted with
            // probability rate(t)/peak.
            t += rng.exponential(peak);
            if t >= horizon_s {
                break;
            }
            let at = SimTime::from_secs_f64(t);
            if !rng.chance(self.rate_at(at) / peak) {
                continue;
            }
            let function = rng.zipf(self.cfg.functions, self.cfg.popularity_s);
            out.push(TraceEvent { at, function });
            // Bursts: correlated immediate retries/fan-outs.
            if rng.chance(self.cfg.burst_p) {
                let burst = 1 + rng.below(3);
                for i in 0..burst {
                    out.push(TraceEvent {
                        at: at + SimTime::from_millis(1 + i),
                        function,
                    });
                }
            }
        }
        out.sort_by_key(|e| e.at);
        out
    }

    /// Maps function indices onto paper workloads: hot ranks get the short
    /// functions (matching Shahrad's "most invocations are short"); the
    /// heavy video job appears only in the cold tail so aggregate demand
    /// stays within a single-node testbed.
    pub fn profile_for(rank: usize) -> WorkloadProfile {
        let kind = match rank % 8 {
            0 | 1 | 2 => WorkloadKind::HelloWorld,
            3 | 4 => WorkloadKind::Io,
            5 => WorkloadKind::Cpu,
            6 => WorkloadKind::Video10s,
            _ => WorkloadKind::Video1m,
        };
        WorkloadProfile::paper(kind)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> TraceConfig {
        TraceConfig {
            functions: 8,
            horizon: SimTime::from_secs(300),
            ..TraceConfig::default()
        }
    }

    #[test]
    fn trace_sorted_and_in_horizon() {
        let trace = TraceGenerator::new(small()).generate();
        assert!(!trace.is_empty());
        assert!(trace.windows(2).all(|w| w[0].at <= w[1].at));
        assert!(trace.iter().all(|e| e.at < SimTime::from_secs(302)));
        assert!(trace.iter().all(|e| e.function < 8));
    }

    #[test]
    fn popularity_is_skewed() {
        let trace = TraceGenerator::new(TraceConfig {
            horizon: SimTime::from_secs(2000),
            ..small()
        })
        .generate();
        let mut counts = vec![0usize; 8];
        for e in &trace {
            counts[e.function] += 1;
        }
        // Rank 0 should dominate rank 7 heavily.
        assert!(counts[0] > 4 * counts[7].max(1), "{counts:?}");
    }

    #[test]
    fn diurnal_rate_varies() {
        let g = TraceGenerator::new(small());
        let trough = g.rate_at(SimTime::ZERO);
        let peak = g.rate_at(SimTime::from_secs(300)); // half period
        assert!(peak > 3.0 * trough, "trough={trough} peak={peak}");
    }

    #[test]
    fn deterministic_per_seed() {
        let a = TraceGenerator::new(small()).generate();
        let b = TraceGenerator::new(small()).generate();
        assert_eq!(a, b);
        let c = TraceGenerator::new(TraceConfig {
            seed: 9,
            ..small()
        })
        .generate();
        assert_ne!(a, c);
    }

    #[test]
    fn bursts_produce_near_simultaneous_arrivals() {
        let trace = TraceGenerator::new(TraceConfig {
            burst_p: 1.0,
            ..small()
        })
        .generate();
        let mut bursty = 0;
        for w in trace.windows(2) {
            if (w[1].at - w[0].at).as_millis_f64() <= 3.0 && w[0].function == w[1].function {
                bursty += 1;
            }
        }
        assert!(bursty > trace.len() / 4, "bursty={bursty}/{}", trace.len());
    }
}
