//! Loader for the public Azure Functions 2019 invocation-trace format
//! (Shahrad et al., ATC '20): a CSV whose rows are functions and whose
//! numeric columns are per-minute invocation counts —
//!
//! ```text
//! HashOwner,HashApp,HashFunction,Trigger,1,2,3,...,1440
//! o1,a1,f1,http,0,3,1,...
//! ```
//!
//! The loader turns that matrix into the deterministic [`TraceEvent`]
//! stream the replayer consumes: a count of `k` in minute `m` becomes `k`
//! events spread evenly inside the minute (no RNG — file in, events out,
//! bit-stable across runs). Function indices are popularity ranks (rank 0 =
//! most invocations), matching [`TraceGenerator::profile_for`]'s
//! "hot ranks are short functions" mapping, with ties broken by first
//! appearance in the file so loading is order-stable.

use std::path::Path;

use crate::simclock::SimTime;
use crate::trace::generator::TraceEvent;

/// A trace materialized from a file.
#[derive(Debug, Clone)]
pub struct LoadedTrace {
    /// Chronologically sorted invocation stream.
    pub events: Vec<TraceEvent>,
    /// Distinct functions (ranks run `0..functions`).
    pub functions: usize,
    /// `HashFunction` values by rank (provenance for reports).
    pub names: Vec<String>,
    /// Horizon covered by the file after scaling.
    pub horizon: SimTime,
}

/// Parses an Azure-Functions-style minute-count CSV. `time_scale`
/// compresses (or stretches) the trace clock: `0.1` replays a day of trace
/// in 2.4 simulated hours. Errors carry the offending line number.
pub fn load_azure_csv(path: &Path, time_scale: f64) -> Result<LoadedTrace, String> {
    if !(time_scale.is_finite() && time_scale > 0.0) {
        return Err(format!("time_scale must be a positive number, got {time_scale}"));
    }
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("cannot read trace file {}: {e}", path.display()))?;
    parse_azure_csv(&text, time_scale).map_err(|e| format!("{}: {e}", path.display()))
}

fn parse_azure_csv(text: &str, time_scale: f64) -> Result<LoadedTrace, String> {
    let mut lines = text.lines().enumerate();
    let (_, header) = lines
        .next()
        .ok_or_else(|| "empty trace file".to_string())?;
    let cols: Vec<&str> = header.split(',').map(str::trim).collect();
    let func_col = cols
        .iter()
        .position(|c| c.eq_ignore_ascii_case("HashFunction"))
        .ok_or_else(|| "header has no HashFunction column".to_string())?;
    // In the real dataset HashFunction is only unique per (owner, app) —
    // identity is the triple when those columns are present.
    let owner_col = cols.iter().position(|c| c.eq_ignore_ascii_case("HashOwner"));
    let app_col = cols.iter().position(|c| c.eq_ignore_ascii_case("HashApp"));
    // Minute columns are the ones whose header parses as a 1-based minute
    // index; everything else (HashOwner, Trigger, ...) is metadata.
    let minute_cols: Vec<(usize, u64)> = cols
        .iter()
        .enumerate()
        .filter_map(|(i, c)| c.parse::<u64>().ok().map(|m| (i, m)))
        .collect();
    if minute_cols.is_empty() {
        return Err("header has no minute-count columns (1,2,...)".to_string());
    }
    if minute_cols.iter().any(|&(_, m)| m == 0) {
        return Err("minute columns are 1-based; header has a column '0'".to_string());
    }

    // Accumulate per function: (first appearance, total, per-minute counts).
    let mut names: Vec<String> = Vec::new();
    let mut index_of: std::collections::BTreeMap<String, usize> = std::collections::BTreeMap::new();
    let mut totals: Vec<u64> = Vec::new();
    let mut counts: Vec<Vec<(u64, u64)>> = Vec::new(); // (minute, count)
    for (lineno, line) in lines {
        if line.trim().is_empty() {
            continue;
        }
        let fields: Vec<&str> = line.split(',').map(str::trim).collect();
        let name = fields
            .get(func_col)
            .filter(|f| !f.is_empty())
            .ok_or_else(|| format!("line {}: missing HashFunction", lineno + 1))?;
        let part = |col: Option<usize>| col.and_then(|c| fields.get(c)).copied().unwrap_or("");
        let key = format!("{}/{}/{name}", part(owner_col), part(app_col));
        let idx = match index_of.get(&key) {
            Some(&i) => i,
            None => {
                names.push((*name).to_string());
                index_of.insert(key, names.len() - 1);
                totals.push(0);
                counts.push(Vec::new());
                names.len() - 1
            }
        };
        for &(col, minute) in &minute_cols {
            let raw = fields.get(col).copied().unwrap_or("0");
            if raw.is_empty() {
                continue;
            }
            let k: u64 = raw.parse().map_err(|_| {
                format!("line {}: minute {minute} count '{raw}' is not a number", lineno + 1)
            })?;
            if k > 0 {
                totals[idx] += k;
                counts[idx].push((minute, k));
            }
        }
    }
    if names.is_empty() {
        return Err("trace file has a header but no function rows".to_string());
    }

    // Rank by total invocations, descending; first appearance breaks ties
    // (sort_by on the index pair is stable by construction).
    let mut order: Vec<usize> = (0..names.len()).collect();
    order.sort_by(|&a, &b| totals[b].cmp(&totals[a]).then(a.cmp(&b)));
    let mut rank_of = vec![0usize; names.len()];
    for (rank, &orig) in order.iter().enumerate() {
        rank_of[orig] = rank;
    }

    let mut events = Vec::new();
    let mut max_minute = 0u64;
    for (orig, per_minute) in counts.iter().enumerate() {
        let rank = rank_of[orig];
        for &(minute, k) in per_minute {
            max_minute = max_minute.max(minute);
            let minute_start = (minute - 1) as f64 * 60.0;
            for i in 0..k {
                // Even spacing inside the minute, offset half a slot so
                // events never collide with the minute boundary.
                let offset = (i as f64 + 0.5) * 60.0 / k as f64;
                events.push(TraceEvent {
                    at: SimTime::from_secs_f64((minute_start + offset) * time_scale),
                    function: rank,
                });
            }
        }
    }
    events.sort_by_key(|e| (e.at, e.function));
    Ok(LoadedTrace {
        events,
        functions: names.len(),
        names: order.into_iter().map(|i| names[i].clone()).collect(),
        horizon: SimTime::from_secs_f64(max_minute as f64 * 60.0 * time_scale),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
HashOwner,HashApp,HashFunction,Trigger,1,2,3
o1,a1,hot,http,4,2,0
o1,a1,cool,timer,0,1,0
o2,a2,mid,queue,1,1,1
";

    #[test]
    fn parses_counts_into_ranked_events() {
        let t = parse_azure_csv(SAMPLE, 1.0).unwrap();
        assert_eq!(t.functions, 3);
        // hot (6 total) > mid (3) > cool (1).
        assert_eq!(t.names, vec!["hot", "mid", "cool"]);
        assert_eq!(t.events.len(), 10);
        let hot: Vec<_> = t.events.iter().filter(|e| e.function == 0).collect();
        assert_eq!(hot.len(), 6);
        // Minute 1's four hot events spread evenly: 7.5, 22.5, 37.5, 52.5 s.
        assert_eq!(hot[0].at, SimTime::from_secs_f64(7.5));
        assert_eq!(hot[3].at, SimTime::from_secs_f64(52.5));
        // Sorted chronologically, inside the 3-minute horizon.
        assert!(t.events.windows(2).all(|w| w[0].at <= w[1].at));
        assert_eq!(t.horizon, SimTime::from_secs(180));
        assert!(t.events.iter().all(|e| e.at < t.horizon));
    }

    #[test]
    fn time_scale_compresses_the_clock() {
        let full = parse_azure_csv(SAMPLE, 1.0).unwrap();
        let tenth = parse_azure_csv(SAMPLE, 0.1).unwrap();
        assert_eq!(full.events.len(), tenth.events.len());
        assert_eq!(tenth.horizon, SimTime::from_secs(18));
        for (a, b) in full.events.iter().zip(&tenth.events) {
            assert_eq!(a.function, b.function);
            assert!((a.at.as_secs_f64() * 0.1 - b.at.as_secs_f64()).abs() < 1e-6);
        }
    }

    #[test]
    fn rank_ties_break_by_first_appearance() {
        let csv = "HashFunction,1\nb,2\na,2\n";
        let t = parse_azure_csv(csv, 1.0).unwrap();
        assert_eq!(t.names, vec!["b", "a"]);
    }

    #[test]
    fn duplicate_function_rows_accumulate() {
        // Identity is the (owner, app, function) triple; rows repeating
        // the same triple accumulate into one rank.
        let csv = "HashFunction,1,2\nf,1,0\nf,0,2\n";
        let t = parse_azure_csv(csv, 1.0).unwrap();
        assert_eq!(t.functions, 1);
        assert_eq!(t.events.len(), 3);
    }

    #[test]
    fn same_function_hash_in_different_apps_stays_distinct() {
        // HashFunction values are only unique per (owner, app) in the real
        // dataset — a collision across apps must not merge the functions.
        let csv = "HashOwner,HashApp,HashFunction,1\no1,a1,f,3\no1,a2,f,1\n";
        let t = parse_azure_csv(csv, 1.0).unwrap();
        assert_eq!(t.functions, 2);
        assert_eq!(t.names, vec!["f", "f"]);
        let rank0 = t.events.iter().filter(|e| e.function == 0).count();
        let rank1 = t.events.iter().filter(|e| e.function == 1).count();
        assert_eq!((rank0, rank1), (3, 1));
    }

    #[test]
    fn errors_are_specific() {
        assert!(parse_azure_csv("", 1.0).unwrap_err().contains("empty"));
        assert!(parse_azure_csv("HashOwner,1\nx,1\n", 1.0)
            .unwrap_err()
            .contains("HashFunction"));
        assert!(parse_azure_csv("HashFunction,Trigger\nf,http\n", 1.0)
            .unwrap_err()
            .contains("minute-count"));
        assert!(parse_azure_csv("HashFunction,0,1\nf,2,1\n", 1.0)
            .unwrap_err()
            .contains("1-based"));
        let bad = parse_azure_csv("HashFunction,1\nf,many\n", 1.0).unwrap_err();
        assert!(bad.contains("line 2") && bad.contains("many"), "{bad}");
        assert!(parse_azure_csv("HashFunction,1\n", 1.0)
            .unwrap_err()
            .contains("no function rows"));
    }

    #[test]
    fn file_roundtrip_via_tempfile() {
        let dir = std::env::temp_dir().join(format!("kinetic-trace-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("azure.csv");
        std::fs::write(&path, SAMPLE).unwrap();
        let t = load_azure_csv(&path, 1.0).unwrap();
        assert_eq!(t.events.len(), 10);
        assert!(load_azure_csv(&dir.join("missing.csv"), 1.0)
            .unwrap_err()
            .contains("cannot read"));
        assert!(load_azure_csv(&path, 0.0).unwrap_err().contains("time_scale"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
