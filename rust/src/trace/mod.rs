//! Azure-Functions-style trace generation and replay.
//!
//! Shahrad et al. (ATC '20), which the paper cites for "over 50% of
//! functions execute in less than one second", characterize production FaaS
//! traffic as: heavily skewed per-function popularity (Zipf-like), diurnal
//! rate variation, and bursty inter-arrivals (CV > 1). The generator
//! reproduces those properties synthetically so the `trace_replay` example
//! can compare the three policies on realistic multi-tenant traffic —
//! the paper's substitution for a production trace (DESIGN.md §1).

pub mod generator;
pub mod loader;
pub mod replay;

pub use generator::{TraceConfig, TraceEvent, TraceGenerator};
pub use loader::{load_azure_csv, LoadedTrace};
pub use replay::{replay, replay_with, ReplayConfig, ReplayReport};
