//! Replays a generated trace against the platform under one policy and
//! reports latency + reservation cost — the multi-tenant comparison the
//! paper's §3 motivates ("resources ... can be dynamically allocated based
//! on incoming requests").

use std::collections::BTreeMap;

use crate::coordinator::platform::Simulation;
use crate::policy::{PlatformParams, Policy};
use crate::simclock::SimTime;
use crate::trace::generator::{TraceEvent, TraceGenerator};
use crate::util::stats::Samples;

/// Outcome of one policy's replay.
#[derive(Debug, Clone)]
pub struct ReplayReport {
    pub policy: Policy,
    pub completed: u64,
    pub failed: u64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub cold_starts: u64,
    /// Average committed CPU over the replay, milliCPU.
    pub avg_committed_mcpu: f64,
    /// Total pods created (churn).
    pub pods_created: u64,
    pub wall: SimTime,
}

/// Replays `trace` (over `functions` distinct functions) under `policy`.
pub fn replay(
    trace: &[TraceEvent],
    functions: usize,
    policy: Policy,
    seed: u64,
) -> ReplayReport {
    let mut sim = Simulation::with_params(PlatformParams::with_seed(seed));
    // Deploy one service per function rank. Multi-tenant traffic needs
    // horizontal headroom too: allow the KPA to scale out to a few pods per
    // function (the paper's future-work "holistic vertical + horizontal"
    // setting), with a concurrency target so heavy functions fan out.
    let mut names: BTreeMap<usize, String> = BTreeMap::new();
    for rank in 0..functions {
        let name = format!("fn-{rank}");
        let mut cfg = policy.revision_config();
        cfg.max_scale = 4;
        cfg.target_concurrency = 2.0;
        cfg.container_concurrency = 2;
        let svc = crate::coordinator::service::Service::with_config(
            &name,
            TraceGenerator::profile_for(rank),
            policy,
            cfg,
        );
        sim.deploy_service(svc);
        names.insert(rank, name);
    }
    sim.run(); // bring up min-scale pods

    let start = sim.now();
    for ev in trace {
        sim.submit_at(start + ev.at, &names[&ev.function]);
    }
    sim.run();

    let now = sim.now();
    let mut lat = Samples::new();
    let mut completed = 0;
    let mut failed = 0;
    let mut cold = 0;
    for (_, m) in sim.world.metrics.services() {
        completed += m.completed;
        failed += m.failed;
        cold += m.cold_starts;
        for &v in m.latency_ms.values() {
            lat.record(v);
        }
    }
    ReplayReport {
        policy,
        completed,
        failed,
        mean_ms: lat.mean(),
        p50_ms: lat.percentile(50.0),
        p99_ms: lat.percentile(99.0),
        cold_starts: cold,
        avg_committed_mcpu: sim.world.metrics.committed_cpu.average_mcpu(now),
        pods_created: sim.world.metrics.pods_created,
        wall: now.saturating_sub(start),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::generator::TraceConfig;

    fn tiny_trace() -> (Vec<TraceEvent>, usize) {
        let cfg = TraceConfig {
            functions: 4,
            peak_rate: 2.0,
            horizon: SimTime::from_secs(120),
            ..TraceConfig::default()
        };
        (TraceGenerator::new(cfg).generate(), 4)
    }

    #[test]
    fn all_policies_complete_the_trace() {
        let (trace, n) = tiny_trace();
        for policy in Policy::ALL {
            let r = replay(&trace, n, policy, 3);
            assert_eq!(r.completed + r.failed, trace.len() as u64, "{policy:?}");
            assert_eq!(r.failed, 0, "{policy:?}");
            assert!(r.mean_ms > 0.0);
        }
    }

    #[test]
    fn warm_fastest_cold_cheapest_reservation() {
        let (trace, n) = tiny_trace();
        let cold = replay(&trace, n, Policy::Cold, 3);
        let warm = replay(&trace, n, Policy::Warm, 3);
        let inp = replay(&trace, n, Policy::InPlace, 3);

        // Latency: warm < in-place < cold.
        assert!(warm.mean_ms < inp.mean_ms, "warm={} inp={}", warm.mean_ms, inp.mean_ms);
        assert!(inp.mean_ms < cold.mean_ms, "inp={} cold={}", inp.mean_ms, cold.mean_ms);

        // Reservation: in-place commits far less than warm.
        assert!(
            inp.avg_committed_mcpu < warm.avg_committed_mcpu / 3.0,
            "inp={} warm={}",
            inp.avg_committed_mcpu,
            warm.avg_committed_mcpu
        );

        // Churn: cold creates pods repeatedly; warm/in-place only min-scale.
        assert!(cold.pods_created > warm.pods_created);
        assert!(cold.cold_starts > 0);
        assert_eq!(inp.cold_starts, 0);
    }
}
