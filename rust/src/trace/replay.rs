//! Replays a trace against the platform under one policy and reports
//! latency + reservation cost — the multi-tenant comparison the paper's §3
//! motivates ("resources ... can be dynamically allocated based on incoming
//! requests").
//!
//! [`replay`] keeps the original paper-testbed shape (single node,
//! least-loaded routing, the old hard-wired autoscaler knobs) bit-for-bit;
//! [`replay_with`] is the scenario-engine entry point that generalizes the
//! same run over any [`Topology`], [`RoutingPolicy`] and [`ScaleKnobs`].

use std::collections::BTreeMap;

use crate::cluster::topology::Topology;
use crate::coordinator::accounting::{HybridWeights, RoutingPolicy};
use crate::coordinator::event::Event;
use crate::coordinator::platform::Simulation;
use crate::forecast::ForecastConfig;
use crate::knative::config::ScaleKnobs;
use crate::obs::{ObsBundle, ObserveConfig};
use crate::policy::{PlatformParams, Policy};
use crate::simclock::SimTime;
use crate::trace::generator::{TraceEvent, TraceGenerator};
use crate::util::stats::Samples;

/// Everything one replay run depends on beyond the trace itself.
#[derive(Debug, Clone)]
pub struct ReplayConfig {
    /// Distinct function ranks in the trace.
    pub functions: usize,
    pub policy: Policy,
    pub routing: RoutingPolicy,
    pub topology: Topology,
    pub knobs: ScaleKnobs,
    pub hybrid: HybridWeights,
    /// Predictor/driver knobs for the forecast-driven policies.
    pub forecast: ForecastConfig,
    /// Fault-injection schedule; the default is inert (installation is a
    /// no-op and the replay stays bit-identical).
    pub faults: crate::faults::FaultsConfig,
    pub seed: u64,
}

impl ReplayConfig {
    /// The pre-redesign `kinetic trace` shape: paper testbed, least-loaded
    /// routing, per-pod concurrency 2.
    pub fn paper(functions: usize, policy: Policy, seed: u64) -> ReplayConfig {
        ReplayConfig {
            functions,
            policy,
            routing: RoutingPolicy::LeastLoaded,
            topology: Topology::paper(),
            knobs: ScaleKnobs::trace_default(),
            hybrid: HybridWeights::default(),
            forecast: ForecastConfig::default(),
            faults: crate::faults::FaultsConfig::default(),
            seed,
        }
    }
}

/// Outcome of one policy's replay.
#[derive(Debug, Clone)]
pub struct ReplayReport {
    pub policy: Policy,
    pub completed: u64,
    pub failed: u64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub cold_starts: u64,
    pub inplace_scale_ups: u64,
    /// Driver-initiated speculative pre-resizes (predictive-inplace).
    pub speculative_resizes: u64,
    /// Speculation windows that closed with no arrival (re-parked).
    pub mispredictions: u64,
    /// Average committed CPU over the replay, milliCPU.
    pub avg_committed_mcpu: f64,
    /// Total pods created (churn).
    pub pods_created: u64,
    /// Scheduling attempts that found no feasible node (fault runs).
    pub pods_unschedulable: u64,
    /// Pods killed by node crashes.
    pub pods_evicted: u64,
    /// Replacement pods started by crash recovery.
    pub pods_rescheduled: u64,
    /// Resize patches rejected by injected API failures.
    pub resize_failures: u64,
    pub wall: SimTime,
}

/// Replays `trace` (over `functions` distinct functions) under `policy` on
/// the paper testbed — the original subcommand path.
pub fn replay(
    trace: &[TraceEvent],
    functions: usize,
    policy: Policy,
    seed: u64,
) -> ReplayReport {
    replay_with(trace, &ReplayConfig::paper(functions, policy, seed))
}

/// Replays `trace` under an arbitrary topology / routing / knob bundle.
pub fn replay_with(trace: &[TraceEvent], cfg: &ReplayConfig) -> ReplayReport {
    replay_with_observed(trace, cfg, None).0
}

/// [`replay_with`] plus an optional observation plane. With `observe` set,
/// the platform is armed after the settle run so the span/timeline window
/// covers exactly the replayed arrivals; the report is byte-identical to
/// the unobserved run either way.
pub fn replay_with_observed(
    trace: &[TraceEvent],
    cfg: &ReplayConfig,
    observe: Option<&ObserveConfig>,
) -> (ReplayReport, Option<ObsBundle>) {
    let mut sim = Simulation::fleet_with_params(
        cfg.topology.clone(),
        PlatformParams::with_seed(cfg.seed),
    );
    sim.world.routing = cfg.routing;
    sim.world.hybrid_weights = cfg.hybrid;
    // Deploy one service per function rank. Multi-tenant traffic needs
    // horizontal headroom too: the knobs let the KPA scale out to a few
    // pods per function (the paper's future-work "holistic vertical +
    // horizontal" setting), with a concurrency target so heavy functions
    // fan out.
    let mut names: BTreeMap<usize, String> = BTreeMap::new();
    for rank in 0..cfg.functions {
        let name = format!("fn-{rank}");
        let mut rc = cfg.policy.revision_config();
        cfg.knobs.apply(&mut rc);
        cfg.forecast.apply(&mut rc, cfg.policy);
        let svc = crate::coordinator::service::Service::with_config(
            &name,
            TraceGenerator::profile_for(rank),
            cfg.policy,
            rc,
        );
        sim.deploy_service(svc);
        names.insert(rank, name);
    }
    sim.run(); // bring up min-scale pods

    // Arm observation at the start of the measured window (after settle)
    // so spans and gauges cover the replayed arrivals only.
    if let Some(oc) = observe {
        let origin = sim.now();
        sim.world.arm_obs(oc.clone(), cfg.seed, origin);
        if oc.timeline {
            sim.engine.schedule_in(oc.timeline_cadence, Event::ObsTick);
        }
    }

    let start = sim.now();
    for ev in trace {
        sim.submit_at(start + ev.at, &names[&ev.function]);
    }
    // Fault offsets are measured from the same origin as the trace; inert
    // configs return before touching any state (bit-identity).
    sim.world.install_faults(&mut sim.engine, &cfg.faults);
    sim.run();

    // Observed runs harvest at the last *real* event: trailing ObsTicks
    // advance the engine clock past the workload, and the time-averaged
    // gauges below must cover exactly the unobserved run's span.
    let now = sim.world.obs_end_clock().unwrap_or_else(|| sim.now());
    let bundle = sim
        .world
        .take_obs()
        .map(|o| o.finish(sim.engine.queue_stats(), sim.engine.processed()));
    let mut lat = Samples::new();
    let mut completed = 0;
    let mut failed = 0;
    let mut cold = 0;
    let mut ups = 0;
    let mut spec_ups = 0;
    let mut mispred = 0;
    for (_, m) in sim.world.metrics.services() {
        completed += m.completed;
        failed += m.failed;
        cold += m.cold_starts;
        ups += m.inplace_scale_ups;
        spec_ups += m.speculative_resizes;
        mispred += m.mispredictions;
        for &v in m.latency_ms.values() {
            lat.record(v);
        }
    }
    let report = ReplayReport {
        policy: cfg.policy,
        completed,
        failed,
        mean_ms: lat.mean(),
        p50_ms: lat.percentile(50.0),
        p99_ms: lat.percentile(99.0),
        cold_starts: cold,
        inplace_scale_ups: ups,
        speculative_resizes: spec_ups,
        mispredictions: mispred,
        avg_committed_mcpu: sim.world.metrics.committed_cpu.average_mcpu(now),
        pods_created: sim.world.metrics.pods_created,
        pods_unschedulable: sim.world.metrics.pods_unschedulable,
        pods_evicted: sim.world.metrics.pods_evicted,
        pods_rescheduled: sim.world.metrics.pods_rescheduled,
        resize_failures: sim.world.metrics.resize_failures,
        wall: now.saturating_sub(start),
    };
    (report, bundle)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::generator::TraceConfig;

    fn tiny_trace() -> (Vec<TraceEvent>, usize) {
        let cfg = TraceConfig {
            functions: 4,
            peak_rate: 2.0,
            horizon: SimTime::from_secs(120),
            ..TraceConfig::default()
        };
        (TraceGenerator::new(cfg).generate(), 4)
    }

    #[test]
    fn all_policies_complete_the_trace() {
        let (trace, n) = tiny_trace();
        for policy in Policy::ALL {
            let r = replay(&trace, n, policy, 3);
            assert_eq!(r.completed + r.failed, trace.len() as u64, "{policy:?}");
            assert_eq!(r.failed, 0, "{policy:?}");
            assert!(r.mean_ms > 0.0);
        }
    }

    #[test]
    fn warm_fastest_cold_cheapest_reservation() {
        let (trace, n) = tiny_trace();
        let cold = replay(&trace, n, Policy::Cold, 3);
        let warm = replay(&trace, n, Policy::Warm, 3);
        let inp = replay(&trace, n, Policy::InPlace, 3);

        // Latency: warm < in-place < cold.
        assert!(warm.mean_ms < inp.mean_ms, "warm={} inp={}", warm.mean_ms, inp.mean_ms);
        assert!(inp.mean_ms < cold.mean_ms, "inp={} cold={}", inp.mean_ms, cold.mean_ms);

        // Reservation: in-place commits far less than warm.
        assert!(
            inp.avg_committed_mcpu < warm.avg_committed_mcpu / 3.0,
            "inp={} warm={}",
            inp.avg_committed_mcpu,
            warm.avg_committed_mcpu
        );

        // Churn: cold creates pods repeatedly; warm/in-place only min-scale.
        assert!(cold.pods_created > warm.pods_created);
        assert!(cold.cold_starts > 0);
        assert_eq!(inp.cold_starts, 0);
        // In-place resizes around requests; the others never do.
        assert!(inp.inplace_scale_ups > 0);
        assert_eq!(warm.inplace_scale_ups, 0);
    }

    /// The generalized entry point with the paper bundle is the legacy
    /// replay, bit for bit — the scenario engine rides this equivalence.
    #[test]
    fn replay_with_paper_bundle_matches_legacy() {
        let (trace, n) = tiny_trace();
        for policy in Policy::ALL {
            let legacy = replay(&trace, n, policy, 7);
            let general = replay_with(&trace, &ReplayConfig::paper(n, policy, 7));
            assert_eq!(legacy.mean_ms.to_bits(), general.mean_ms.to_bits(), "{policy:?}");
            assert_eq!(legacy.completed, general.completed);
            assert_eq!(legacy.pods_created, general.pods_created);
        }
    }

    /// A replay over a multi-node topology spreads pods and still completes
    /// everything — the ROADMAP's "replay over hetero" item.
    #[test]
    fn replay_over_hetero_topology() {
        let (trace, n) = tiny_trace();
        let cfg = ReplayConfig {
            topology: Topology::hetero_preset(3),
            routing: RoutingPolicy::Locality,
            ..ReplayConfig::paper(n, Policy::Warm, 3)
        };
        let r = replay_with(&trace, &cfg);
        assert_eq!(r.failed, 0);
        assert_eq!(r.completed, trace.len() as u64);
    }
}
