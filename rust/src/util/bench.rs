//! Micro-benchmark harness (criterion is not in the offline mirror).
//!
//! Provides warmup + timed iterations with mean/σ/percentiles, a `black_box`
//! to defeat const-folding, and a runner that understands the conventional
//! `cargo bench -- <filter>` argument so individual paper artifacts
//! (e.g. `fig2`, `table3`) can be regenerated alone.

use std::time::{Duration, Instant};

use crate::util::stats::Samples;

/// Opaque identity function the optimizer cannot see through.
#[inline]
pub fn black_box<T>(x: T) -> T {
    // std::hint::black_box is stable since 1.66.
    std::hint::black_box(x)
}

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn mean(&self) -> Duration {
        Duration::from_nanos(self.mean_ns as u64)
    }

    /// Human-friendly one-liner, criterion-style.
    pub fn line(&self) -> String {
        format!(
            "{:<44} {:>12}/iter  (p50 {:>10}, p99 {:>10}, n={})",
            self.name,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p99_ns),
            self.iters
        )
    }
}

/// Formats nanoseconds with an adaptive unit.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.1} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1_000_000.0)
    } else {
        format!("{:.3} s", ns / 1_000_000_000.0)
    }
}

/// Benchmark configuration.
#[derive(Debug, Clone)]
pub struct BenchConfig {
    pub warmup: Duration,
    pub measure: Duration,
    pub min_iters: u64,
    pub max_iters: u64,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(800),
            min_iters: 10,
            max_iters: 10_000_000,
        }
    }
}

/// Times `f` per the config; each sample is one call.
pub fn bench_fn<F: FnMut()>(name: &str, cfg: &BenchConfig, mut f: F) -> BenchResult {
    // Warmup.
    let start = Instant::now();
    let mut warm_iters = 0u64;
    while start.elapsed() < cfg.warmup && warm_iters < cfg.max_iters {
        f();
        warm_iters += 1;
    }

    let mut samples = Samples::new();
    let start = Instant::now();
    let mut iters = 0u64;
    while (start.elapsed() < cfg.measure || iters < cfg.min_iters) && iters < cfg.max_iters {
        let t0 = Instant::now();
        f();
        samples.record(t0.elapsed().as_nanos() as f64);
        iters += 1;
    }

    BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: samples.mean(),
        std_ns: samples.std_dev(),
        p50_ns: samples.percentile(50.0),
        p99_ns: samples.percentile(99.0),
        min_ns: samples.min(),
    }
}

/// Times `f` in batches of `batch` calls per sample — for sub-100ns bodies
/// where per-call `Instant::now()` overhead would dominate.
pub fn bench_batched<F: FnMut()>(
    name: &str,
    cfg: &BenchConfig,
    batch: u64,
    mut f: F,
) -> BenchResult {
    assert!(batch > 0);
    let start = Instant::now();
    while start.elapsed() < cfg.warmup {
        for _ in 0..batch {
            f();
        }
    }
    let mut samples = Samples::new();
    let mut iters = 0u64;
    let start = Instant::now();
    while (start.elapsed() < cfg.measure || iters < cfg.min_iters) && iters < cfg.max_iters {
        let t0 = Instant::now();
        for _ in 0..batch {
            f();
        }
        samples.record(t0.elapsed().as_nanos() as f64 / batch as f64);
        iters += batch;
    }
    BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: samples.mean(),
        std_ns: samples.std_dev(),
        p50_ns: samples.percentile(50.0),
        p99_ns: samples.percentile(99.0),
        min_ns: samples.min(),
    }
}

/// Bench-binary runner: registers named sections and honours the
/// `cargo bench -- <filter>` convention.
pub struct Runner {
    filter: Option<String>,
    pub results: Vec<BenchResult>,
}

impl Default for Runner {
    fn default() -> Self {
        Runner::from_args()
    }
}

impl Runner {
    pub fn from_args() -> Runner {
        // cargo passes `--bench`; any other non-flag arg is a filter.
        let filter = std::env::args()
            .skip(1)
            .find(|a| !a.starts_with('-'));
        Runner {
            filter,
            results: Vec::new(),
        }
    }

    pub fn with_filter(filter: Option<String>) -> Runner {
        Runner {
            filter,
            results: Vec::new(),
        }
    }

    /// Should the section named `name` run under the current filter?
    pub fn enabled(&self, name: &str) -> bool {
        match &self.filter {
            Some(f) => name.contains(f.as_str()),
            None => true,
        }
    }

    /// Runs a whole *section* (a paper table/figure) if enabled.
    pub fn section<F: FnOnce()>(&self, name: &str, f: F) {
        if self.enabled(name) {
            println!("\n### {name}");
            f();
        }
    }

    pub fn bench<F: FnMut()>(&mut self, name: &str, f: F) {
        if !self.enabled(name) {
            return;
        }
        let r = bench_fn(name, &BenchConfig::default(), f);
        println!("{}", r.line());
        self.results.push(r);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick() -> BenchConfig {
        BenchConfig {
            warmup: Duration::from_millis(5),
            measure: Duration::from_millis(20),
            min_iters: 5,
            max_iters: 100_000,
        }
    }

    #[test]
    fn bench_fn_measures_something() {
        let r = bench_fn("spin", &quick(), || {
            let mut acc = 0u64;
            for i in 0..1000 {
                acc = acc.wrapping_add(black_box(i));
            }
            black_box(acc);
        });
        assert!(r.iters >= 5);
        assert!(r.mean_ns > 0.0);
        assert!(r.p99_ns >= r.p50_ns);
        assert!(r.min_ns <= r.mean_ns);
    }

    #[test]
    fn batched_reduces_timer_noise() {
        let r = bench_batched("tiny", &quick(), 1000, || {
            black_box(1u64 + black_box(2u64));
        });
        assert!(r.iters >= 1000);
        // A single add should take < 100ns/iter even on a loaded machine.
        assert!(r.mean_ns < 100.0, "mean={}", r.mean_ns);
    }

    #[test]
    fn filter_controls_sections() {
        let r = Runner::with_filter(Some("fig2".into()));
        assert!(r.enabled("fig2_up"));
        assert!(!r.enabled("table3"));
        let all = Runner::with_filter(None);
        assert!(all.enabled("anything"));
    }

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(12.3), "12.3 ns");
        assert_eq!(fmt_ns(12_300.0), "12.30 µs");
        assert_eq!(fmt_ns(12_300_000.0), "12.30 ms");
        assert_eq!(fmt_ns(2_500_000_000.0), "2.500 s");
    }
}
