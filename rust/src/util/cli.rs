//! A small declarative CLI argument parser (clap is not in the offline
//! mirror). Supports subcommands, `--flag`, `--key value` / `--key=value`,
//! defaults, and auto-generated help.

use std::collections::BTreeMap;
use std::fmt;

/// Hard cap on the shared `--threads` option and the scenario engine's
/// worker clamp — far above any useful count for a ≤4096-cell grid, it
/// only guards against a mistyped huge value spawning thousands of OS
/// threads. One constant so the CLI can never accept what the engine
/// would clamp (or reject what it would run).
pub const MAX_THREADS: usize = 256;

/// Hard cap on the shared `--shards` option and the scenario spec's
/// `shards` knob. A shard can never hold less than one fleet cell, and
/// no committed topology exceeds a few hundred nodes, so 64 is already
/// past the point of diminishing returns; like [`MAX_THREADS`] this only
/// guards against a mistyped huge value.
pub const MAX_SHARDS: u64 = 64;

/// Declared option for a subcommand.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    /// Help text; owned so derived pieces (e.g. the policy-name list
    /// built from `Policy::ALL`) can be composed in at declaration time.
    pub help: String,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

/// A parsed invocation: subcommand plus resolved options.
#[derive(Debug, Clone)]
pub struct Invocation {
    pub command: String,
    opts: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    pub positionals: Vec<String>,
}

#[derive(Debug)]
pub enum CliError {
    UnknownCommand(String),
    UnknownOption(String, String),
    MissingValue(String),
    /// The option was given but its value does not parse / is out of range.
    InvalidValue(String, String),
    Help(String),
}

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CliError::UnknownCommand(c) => write!(f, "unknown command: {c}"),
            CliError::UnknownOption(cmd, o) => {
                write!(f, "unknown option --{o} for command {cmd}")
            }
            CliError::MissingValue(o) => write!(f, "option --{o} requires a value"),
            CliError::InvalidValue(o, msg) => write!(f, "invalid --{o}: {msg}"),
            CliError::Help(text) => write!(f, "{text}"),
        }
    }
}

impl std::error::Error for CliError {}

impl Invocation {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(String::as_str)
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.get(name).copied().unwrap_or(false)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    // -- validated accessors -----------------------------------------------
    //
    // Unlike `get_u64`/`get_f64` (which silently fall back to the default
    // on garbage), these reject unparseable or out-of-range values with
    // the offending option named — the shared parsing path for the
    // `seed`/`rate`/`seconds` options every subcommand declares.

    /// Integer option constrained to `[lo, hi]`.
    pub fn u64_in(&self, name: &str, lo: u64, hi: u64) -> Result<u64, CliError> {
        let raw = self
            .get(name)
            .ok_or_else(|| CliError::MissingValue(name.to_string()))?;
        let v: u64 = raw.parse().map_err(|_| {
            CliError::InvalidValue(name.to_string(), format!("'{raw}' is not an integer"))
        })?;
        if v < lo || v > hi {
            return Err(CliError::InvalidValue(
                name.to_string(),
                format!("{v} is outside [{lo}, {hi}]"),
            ));
        }
        Ok(v)
    }

    /// Float option constrained to `[lo, hi]` (finite).
    pub fn f64_in(&self, name: &str, lo: f64, hi: f64) -> Result<f64, CliError> {
        let raw = self
            .get(name)
            .ok_or_else(|| CliError::MissingValue(name.to_string()))?;
        let v: f64 = raw.parse().map_err(|_| {
            CliError::InvalidValue(name.to_string(), format!("'{raw}' is not a number"))
        })?;
        if !v.is_finite() || v < lo || v > hi {
            return Err(CliError::InvalidValue(
                name.to_string(),
                format!("{v} is outside [{lo}, {hi}]"),
            ));
        }
        Ok(v)
    }

    /// The shared `--seed` option (any u64, but it must parse).
    pub fn seed(&self) -> Result<u64, CliError> {
        self.u64_in("seed", 0, u64::MAX)
    }

    /// The shared `--rate` option: requests/second in (0, 10⁶].
    pub fn rate(&self) -> Result<f64, CliError> {
        self.f64_in("rate", 1e-6, 1e6)
    }

    /// The shared `--seconds` option: a horizon of 1 s up to one year.
    pub fn seconds(&self) -> Result<u64, CliError> {
        self.u64_in("seconds", 1, 31_536_000)
    }

    /// The shared `--threads` option: worker count in `[1, MAX_THREADS]`.
    pub fn threads(&self) -> Result<usize, CliError> {
        self.u64_in("threads", 1, MAX_THREADS as u64)
            .map(|v| v as usize)
    }

    /// The shared `--shards` option: shard count in `[1, MAX_SHARDS]`, or
    /// `None` when the flag was left at its empty default (meaning "use
    /// the spec's `shards` knob, or the classic single-coordinator path").
    pub fn shards(&self) -> Result<Option<u32>, CliError> {
        match self.get("shards") {
            None | Some("") => Ok(None),
            Some(_) => self.u64_in("shards", 1, MAX_SHARDS).map(|v| Some(v as u32)),
        }
    }

    /// A scheduling-policy option (`serve --policy`, `analyze --baseline`,
    /// ...): one `FromStr` path shared with scenario `policies` lists, so
    /// the accepted spellings and the valid-name error text (derived from
    /// `Policy::ALL`) cannot drift between entry points.
    pub fn opt_policy(&self, name: &str) -> Result<crate::policy::Policy, CliError> {
        let raw = self
            .get(name)
            .ok_or_else(|| CliError::MissingValue(name.to_string()))?;
        raw.parse()
            .map_err(|e: String| CliError::InvalidValue(name.to_string(), e))
    }
}

/// A subcommand with its options.
#[derive(Debug, Clone)]
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<OptSpec>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Command {
        Command {
            name,
            about,
            opts: Vec::new(),
        }
    }

    pub fn opt(
        mut self,
        name: &'static str,
        help: impl Into<String>,
        default: &'static str,
    ) -> Self {
        self.opts.push(OptSpec {
            name,
            help: help.into(),
            default: Some(default),
            is_flag: false,
        });
        self
    }

    pub fn opt_req(mut self, name: &'static str, help: impl Into<String>) -> Self {
        self.opts.push(OptSpec {
            name,
            help: help.into(),
            default: None,
            is_flag: false,
        });
        self
    }

    pub fn flag(mut self, name: &'static str, help: impl Into<String>) -> Self {
        self.opts.push(OptSpec {
            name,
            help: help.into(),
            default: None,
            is_flag: true,
        });
        self
    }

    // Shared option declarations — one help string and one validated
    // accessor (`Invocation::{seed, rate, seconds}`) per option, instead of
    // each subcommand re-declaring and re-parsing its own copy.

    pub fn opt_seed(self, default: &'static str) -> Self {
        self.opt("seed", "rng seed", default)
    }

    pub fn opt_rate(self, help: &'static str, default: &'static str) -> Self {
        self.opt("rate", help, default)
    }

    pub fn opt_seconds(self, help: &'static str, default: &'static str) -> Self {
        self.opt("seconds", help, default)
    }

    pub fn opt_threads(self, default: &'static str) -> Self {
        self.opt(
            "threads",
            "worker threads for the run grid (the report is identical at any count)",
            default,
        )
    }

    pub fn opt_shards(self) -> Self {
        self.opt(
            "shards",
            "coordinator shards for one run (the report is byte-identical \
             at any count); empty = the spec's `shards` knob",
            "",
        )
    }

    /// A scheduling-policy option: the caller's description plus the full
    /// policy list derived from `Policy::ALL`, so help text keeps saying
    /// what the option *does* while new variants show up automatically.
    pub fn opt_policy(
        self,
        name: &'static str,
        help: &'static str,
        default: &'static str,
    ) -> Self {
        self.opt(
            name,
            format!("{help} ({})", crate::policy::names_pipes()),
            default,
        )
    }
}

/// Top-level application.
#[derive(Debug, Clone)]
pub struct App {
    pub name: &'static str,
    pub about: &'static str,
    pub commands: Vec<Command>,
}

impl App {
    pub fn new(name: &'static str, about: &'static str) -> App {
        App {
            name,
            about,
            commands: Vec::new(),
        }
    }

    pub fn command(mut self, cmd: Command) -> App {
        self.commands.push(cmd);
        self
    }

    /// Renders the top-level help text.
    pub fn help(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {} <command> [options]\n\nCOMMANDS:\n",
            self.name, self.about, self.name);
        for c in &self.commands {
            s.push_str(&format!("  {:<18} {}\n", c.name, c.about));
        }
        s.push_str(&format!("\nRun `{} <command> --help` for command options.\n", self.name));
        s
    }

    fn command_help(&self, cmd: &Command) -> String {
        let mut s = format!("{} {} — {}\n\nOPTIONS:\n", self.name, cmd.name, cmd.about);
        for o in &cmd.opts {
            let head = if o.is_flag {
                format!("--{}", o.name)
            } else {
                format!("--{} <value>", o.name)
            };
            let default = match o.default {
                Some(d) if !o.is_flag => format!(" [default: {d}]"),
                _ => String::new(),
            };
            s.push_str(&format!("  {:<26} {}{}\n", head, o.help, default));
        }
        s
    }

    /// Parses argv (without the program name).
    pub fn parse(&self, args: &[String]) -> Result<Invocation, CliError> {
        if args.is_empty()
            || args[0] == "--help"
            || args[0] == "-h"
            || args[0] == "help"
        {
            return Err(CliError::Help(self.help()));
        }
        let cmd_name = &args[0];
        let cmd = self
            .commands
            .iter()
            .find(|c| c.name == *cmd_name)
            .ok_or_else(|| CliError::UnknownCommand(cmd_name.clone()))?;

        let mut opts: BTreeMap<String, String> = BTreeMap::new();
        let mut flags: BTreeMap<String, bool> = BTreeMap::new();
        let mut positionals = Vec::new();
        for o in &cmd.opts {
            if let (false, Some(d)) = (o.is_flag, o.default) {
                opts.insert(o.name.to_string(), d.to_string());
            }
        }

        let mut i = 1;
        while i < args.len() {
            let a = &args[i];
            if a == "--help" || a == "-h" {
                return Err(CliError::Help(self.command_help(cmd)));
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = cmd
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| CliError::UnknownOption(cmd.name.to_string(), key.clone()))?;
                if spec.is_flag {
                    flags.insert(key, true);
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            args.get(i)
                                .cloned()
                                .ok_or_else(|| CliError::MissingValue(key.clone()))?
                        }
                    };
                    opts.insert(key, val);
                }
            } else {
                positionals.push(a.clone());
            }
            i += 1;
        }

        Ok(Invocation {
            command: cmd.name.to_string(),
            opts,
            flags,
            positionals,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn app() -> App {
        App::new("kinetic", "serverless platform")
            .command(
                Command::new("exp", "run an experiment")
                    .opt("id", "experiment id", "all")
                    .opt("seed", "rng seed", "42")
                    .flag("verbose", "chatty output"),
            )
            .command(Command::new("serve", "start platform").opt_req("artifacts", "artifact dir"))
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_defaults() {
        let inv = app().parse(&sv(&["exp"])).unwrap();
        assert_eq!(inv.command, "exp");
        assert_eq!(inv.get("id"), Some("all"));
        assert_eq!(inv.get_u64("seed", 0), 42);
        assert!(!inv.flag("verbose"));
    }

    #[test]
    fn parses_values_and_flags() {
        let inv = app()
            .parse(&sv(&["exp", "--id", "t1", "--verbose", "--seed=7"]))
            .unwrap();
        assert_eq!(inv.get("id"), Some("t1"));
        assert_eq!(inv.get_u64("seed", 0), 7);
        assert!(inv.flag("verbose"));
    }

    #[test]
    fn positionals_collected() {
        let inv = app().parse(&sv(&["exp", "extra1", "extra2"])).unwrap();
        assert_eq!(inv.positionals, vec!["extra1", "extra2"]);
    }

    #[test]
    fn unknown_command_and_option() {
        assert!(matches!(
            app().parse(&sv(&["nope"])),
            Err(CliError::UnknownCommand(_))
        ));
        assert!(matches!(
            app().parse(&sv(&["exp", "--bogus", "1"])),
            Err(CliError::UnknownOption(_, _))
        ));
    }

    #[test]
    fn missing_value_detected() {
        assert!(matches!(
            app().parse(&sv(&["exp", "--id"])),
            Err(CliError::MissingValue(_))
        ));
    }

    #[test]
    fn validated_accessors_reject_garbage_and_ranges() {
        let app = App::new("k", "t").command(
            Command::new("go", "x")
                .opt_seed("42")
                .opt_rate("rps", "0.5")
                .opt_seconds("horizon", "300")
                .opt_threads("1"),
        );
        let inv = app.parse(&sv(&["go"])).unwrap();
        assert_eq!(inv.seed().unwrap(), 42);
        assert_eq!(inv.rate().unwrap(), 0.5);
        assert_eq!(inv.seconds().unwrap(), 300);
        assert_eq!(inv.threads().unwrap(), 1);

        let inv = app.parse(&sv(&["go", "--threads", "0"])).unwrap();
        assert!(inv.threads().is_err());
        let inv = app.parse(&sv(&["go", "--threads", "8"])).unwrap();
        assert_eq!(inv.threads().unwrap(), 8);

        let inv = app.parse(&sv(&["go", "--seed", "banana"])).unwrap();
        let e = inv.seed().unwrap_err().to_string();
        assert!(e.contains("--seed") && e.contains("banana"), "{e}");

        let inv = app.parse(&sv(&["go", "--rate", "0"])).unwrap();
        let e = inv.rate().unwrap_err().to_string();
        assert!(e.contains("--rate") && e.contains("outside"), "{e}");

        let inv = app.parse(&sv(&["go", "--rate", "inf"])).unwrap();
        assert!(inv.rate().is_err());

        let inv = app.parse(&sv(&["go", "--seconds", "0"])).unwrap();
        assert!(inv.seconds().is_err());

        // The legacy accessor still silently falls back (documented).
        let inv = app.parse(&sv(&["go", "--seed", "banana"])).unwrap();
        assert_eq!(inv.get_u64("seed", 7), 7);
    }

    #[test]
    fn shards_option_is_optional_and_range_checked() {
        let app =
            App::new("k", "t").command(Command::new("go", "x").opt_shards());

        // Left at the empty default: no override requested.
        let inv = app.parse(&sv(&["go"])).unwrap();
        assert_eq!(inv.shards().unwrap(), None);

        let inv = app.parse(&sv(&["go", "--shards", "1"])).unwrap();
        assert_eq!(inv.shards().unwrap(), Some(1));
        let inv = app.parse(&sv(&["go", "--shards", "64"])).unwrap();
        assert_eq!(inv.shards().unwrap(), Some(64));

        let inv = app.parse(&sv(&["go", "--shards", "0"])).unwrap();
        let e = inv.shards().unwrap_err().to_string();
        assert!(e.contains("--shards") && e.contains("outside"), "{e}");
        let inv = app.parse(&sv(&["go", "--shards", "65"])).unwrap();
        assert!(inv.shards().is_err());
        let inv = app.parse(&sv(&["go", "--shards", "many"])).unwrap();
        let e = inv.shards().unwrap_err().to_string();
        assert!(e.contains("not an integer"), "{e}");

        // A command that never declared the option reports None too.
        let bare = App::new("k", "t").command(Command::new("go", "x"));
        let inv = bare.parse(&sv(&["go"])).unwrap();
        assert_eq!(inv.shards().unwrap(), None);
    }

    #[test]
    fn policy_option_parses_through_the_shared_fromstr() {
        use crate::policy::Policy;
        let app = App::new("k", "t").command(Command::new("go", "x").opt_policy(
            "baseline",
            "policy the ratios are computed against",
            "cold",
        ));
        let inv = app.parse(&sv(&["go"])).unwrap();
        assert_eq!(inv.opt_policy("baseline").unwrap(), Policy::Cold);
        let inv = app
            .parse(&sv(&["go", "--baseline", "predictive-inplace"]))
            .unwrap();
        assert_eq!(
            inv.opt_policy("baseline").unwrap(),
            Policy::PredictiveInPlace
        );
        // The rejection names the option and lists every valid policy.
        let inv = app.parse(&sv(&["go", "--baseline", "tepid"])).unwrap();
        let e = inv.opt_policy("baseline").unwrap_err().to_string();
        assert!(e.contains("--baseline"), "{e}");
        for p in Policy::ALL {
            assert!(e.contains(p.name()), "missing {} in {e}", p.name());
        }
        // The declared help text keeps the description AND carries the
        // derived name list.
        if let Err(CliError::Help(h)) = app.parse(&sv(&["go", "--help"])) {
            assert!(h.contains("pooled"), "{h}");
            assert!(h.contains("computed against"), "{h}");
        } else {
            panic!("help expected");
        }
    }

    #[test]
    fn help_requested() {
        assert!(matches!(app().parse(&sv(&[])), Err(CliError::Help(_))));
        assert!(matches!(
            app().parse(&sv(&["exp", "--help"])),
            Err(CliError::Help(_))
        ));
        if let Err(CliError::Help(h)) = app().parse(&sv(&["exp", "--help"])) {
            assert!(h.contains("--seed"));
            assert!(h.contains("default: 42"));
        }
    }
}
