//! Service-name interning: the hot path carries dense [`ServiceId`]s
//! instead of `Arc<str>`/`String` keys.
//!
//! Ids are assigned in first-intern order (deploy order on the platform),
//! so the same spec produces the same id assignment on every run, thread
//! count and shard count. Rendering stays canonical through
//! [`Interner::ids_by_name`], which walks the side index in lexicographic
//! name order — the exact order the old `BTreeMap<String, _>` tables
//! iterated in, so reports are byte-identical to the map era.
//!
//! Names survive only at the boundaries: spec parse / `deploy` interns,
//! report render resolves ids back via [`Interner::name`]. Everything in
//! between — events, requests, forecast state, fault sweeps — moves a
//! `Copy` u32.

use std::collections::BTreeMap;
use std::sync::Arc;

/// Dense index of an interned service name. `ServiceId(n)` is the `n`-th
/// distinct name ever interned (first-seen order).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct ServiceId(pub u32);

impl ServiceId {
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

/// The intern table: name → id (lookup) and id → name (render).
#[derive(Debug, Clone, Default)]
pub struct Interner {
    /// Indexed by `ServiceId`; assignment order.
    names: Vec<Arc<str>>,
    /// Lexicographic side index (canonical render/iteration order).
    by_name: BTreeMap<Arc<str>, ServiceId>,
}

impl Interner {
    pub fn new() -> Interner {
        Interner::default()
    }

    /// Returns the id for `name`, allocating the next dense id on first
    /// sight. Idempotent: interning an existing name is a pure lookup.
    pub fn intern(&mut self, name: &str) -> ServiceId {
        if let Some(&id) = self.by_name.get(name) {
            return id;
        }
        let id = ServiceId(self.names.len() as u32);
        let arc: Arc<str> = Arc::from(name);
        self.names.push(Arc::clone(&arc));
        self.by_name.insert(arc, id);
        id
    }

    /// Lookup without allocation.
    pub fn get(&self, name: &str) -> Option<ServiceId> {
        self.by_name.get(name).copied()
    }

    /// The name behind an id. Panics on an id from a different interner
    /// that is out of range — ids are not portable across tables.
    pub fn name(&self, id: ServiceId) -> &Arc<str> {
        &self.names[id.index()]
    }

    pub fn len(&self) -> usize {
        self.names.len()
    }

    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }

    /// Ids in first-interned (assignment) order.
    pub fn ids(&self) -> impl Iterator<Item = ServiceId> + '_ {
        (0..self.names.len() as u32).map(ServiceId)
    }

    /// Ids in lexicographic name order — the canonical iteration order
    /// everywhere the old string-keyed `BTreeMap`s were walked (render
    /// passes and RNG-bearing sweeps alike).
    pub fn ids_by_name(&self) -> impl Iterator<Item = ServiceId> + '_ {
        self.by_name.values().copied()
    }

    /// `(name, id)` pairs in lexicographic name order.
    pub fn iter_by_name(&self) -> impl Iterator<Item = (&Arc<str>, ServiceId)> + '_ {
        self.by_name.iter().map(|(n, &id)| (n, id))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_seen_order_assignment() {
        let mut t = Interner::new();
        assert_eq!(t.intern("fn-1"), ServiceId(0));
        assert_eq!(t.intern("fn-0"), ServiceId(1));
        assert_eq!(t.intern("fn-1"), ServiceId(0), "re-intern is a lookup");
        assert_eq!(t.len(), 2);
        assert_eq!(&**t.name(ServiceId(1)), "fn-0");
    }

    #[test]
    fn name_order_differs_from_id_order() {
        // fn-10 sorts before fn-2 lexicographically but interns after it —
        // the divergence the canonical render order has to paper over.
        let mut t = Interner::new();
        for n in ["fn-2", "fn-10"] {
            t.intern(n);
        }
        let by_id: Vec<_> = t.ids().collect();
        assert_eq!(by_id, vec![ServiceId(0), ServiceId(1)]);
        let by_name: Vec<_> = t.ids_by_name().collect();
        assert_eq!(by_name, vec![ServiceId(1), ServiceId(0)]);
    }

    #[test]
    fn get_does_not_allocate() {
        let mut t = Interner::new();
        assert_eq!(t.get("missing"), None);
        let id = t.intern("svc");
        assert_eq!(t.get("svc"), Some(id));
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn deterministic_across_tables() {
        let names = ["b", "a", "c", "a", "b", "d"];
        let mut x = Interner::new();
        let mut y = Interner::new();
        let ix: Vec<_> = names.iter().map(|n| x.intern(n)).collect();
        let iy: Vec<_> = names.iter().map(|n| y.intern(n)).collect();
        assert_eq!(ix, iy);
    }
}
