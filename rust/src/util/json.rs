//! Minimal JSON: parser, value model, and writer.
//!
//! Used for the artifact manifest (`artifacts/manifest.json`), experiment
//! result export, and platform config files. Implements RFC 8259 minus
//! `\u` surrogate-pair edge cases we never emit (they still parse).

use std::collections::BTreeMap;
use std::fmt;
use std::path::{Path, PathBuf};

/// A JSON value. Object keys are sorted (BTreeMap) so output is canonical.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, PartialEq)]
pub enum JsonError {
    Eof(usize),
    Unexpected(usize, char),
    BadNumber(usize),
    BadEscape(usize),
    Trailing(usize),
    MissingField(String),
    WrongType(String),
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            JsonError::Eof(i) => write!(f, "unexpected end of input at byte {i}"),
            JsonError::Unexpected(i, c) => write!(f, "unexpected character '{c}' at byte {i}"),
            JsonError::BadNumber(i) => write!(f, "invalid number at byte {i}"),
            JsonError::BadEscape(i) => write!(f, "invalid escape at byte {i}"),
            JsonError::Trailing(i) => write!(f, "trailing data at byte {i}"),
            JsonError::MissingField(s) => write!(f, "missing field '{s}'"),
            JsonError::WrongType(s) => write!(f, "wrong type for '{s}'"),
        }
    }
}

impl std::error::Error for JsonError {}

/// Writes `<dir>/<prefix>_<slug>.json` (pretty) and returns the path,
/// slugging non-alphanumeric name characters to `-` — the one naming rule
/// for every persisted report document (`scenario_*`, `analysis_*`), so
/// the file pairs a run produces can never drift apart.
pub fn save_named(dir: &Path, prefix: &str, name: &str, doc: &Json) -> std::io::Result<PathBuf> {
    std::fs::create_dir_all(dir)?;
    let slug: String = name
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() { c } else { '-' })
        .collect();
    let path = dir.join(format!("{prefix}_{slug}.json"));
    std::fs::write(&path, doc.to_string_pretty())?;
    Ok(path)
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: s.as_bytes(),
            i: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(JsonError::Trailing(p.i));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as u64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// Required-field helpers that surface good errors for config parsing.
    pub fn req_str(&self, key: &str) -> Result<&str, JsonError> {
        self.get(key)
            .ok_or_else(|| JsonError::MissingField(key.into()))?
            .as_str()
            .ok_or_else(|| JsonError::WrongType(key.into()))
    }

    pub fn req_f64(&self, key: &str) -> Result<f64, JsonError> {
        self.get(key)
            .ok_or_else(|| JsonError::MissingField(key.into()))?
            .as_f64()
            .ok_or_else(|| JsonError::WrongType(key.into()))
    }

    pub fn req_u64(&self, key: &str) -> Result<u64, JsonError> {
        self.get(key)
            .ok_or_else(|| JsonError::MissingField(key.into()))?
            .as_u64()
            .ok_or_else(|| JsonError::WrongType(key.into()))
    }

    pub fn req_arr(&self, key: &str) -> Result<&[Json], JsonError> {
        self.get(key)
            .ok_or_else(|| JsonError::MissingField(key.into()))?
            .as_arr()
            .ok_or_else(|| JsonError::WrongType(key.into()))
    }

    /// Optional field with default.
    pub fn opt_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Json::as_f64).unwrap_or(default)
    }

    pub fn opt_str<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(Json::as_str).unwrap_or(default)
    }

    pub fn opt_bool(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Json::as_bool).unwrap_or(default)
    }

    // -- builders ----------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    /// Compact serialization.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty serialization with 2-space indent.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, item)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    item.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string_compact())
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}

impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}

impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8, JsonError> {
        self.b.get(self.i).copied().ok_or(JsonError::Eof(self.i))
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        let got = self.peek()?;
        if got != c {
            return Err(JsonError::Unexpected(self.i, got as char));
        }
        self.i += 1;
        Ok(())
    }

    fn literal(&mut self, lit: &str) -> Result<(), JsonError> {
        if self.b[self.i..].starts_with(lit.as_bytes()) {
            self.i += lit.len();
            Ok(())
        } else {
            Err(JsonError::Unexpected(self.i, self.peek()? as char))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => {
                self.literal("true")?;
                Ok(Json::Bool(true))
            }
            b'f' => {
                self.literal("false")?;
                Ok(Json::Bool(false))
            }
            b'n' => {
                self.literal("null")?;
                Ok(Json::Null)
            }
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(JsonError::Unexpected(self.i, c as char)),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => return Err(JsonError::Unexpected(self.i, c as char)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => return Err(JsonError::Unexpected(self.i, c as char)),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let esc = self.peek()?;
                    self.i += 1;
                    match esc {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(JsonError::Eof(self.i));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| JsonError::BadEscape(self.i))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| JsonError::BadEscape(self.i))?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(JsonError::BadEscape(self.i - 1)),
                    }
                }
                c if c < 0x80 => s.push(c as char),
                _ => {
                    // Multi-byte UTF-8: copy the full sequence.
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    if start + len > self.b.len() {
                        return Err(JsonError::Eof(self.i));
                    }
                    let chunk = std::str::from_utf8(&self.b[start..start + len])
                        .map_err(|_| JsonError::BadEscape(start))?;
                    s.push_str(chunk);
                    self.i = start + len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek()? == b'-' {
            self.i += 1;
        }
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| JsonError::BadNumber(start))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(j.get("c").unwrap().as_str(), Some("x"));
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn parse_escapes() {
        let j = Json::parse(r#""a\nb\t\"c\" A""#).unwrap();
        assert_eq!(j.as_str(), Some("a\nb\t\"c\" A"));
    }

    #[test]
    fn parse_unicode_passthrough() {
        let j = Json::parse(r#""héllo ✓""#).unwrap();
        assert_eq!(j.as_str(), Some("héllo ✓"));
    }

    #[test]
    fn parse_errors() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn round_trip() {
        let src = r#"{"arr":[1,2.5,"x"],"nested":{"t":true},"z":null}"#;
        let j = Json::parse(src).unwrap();
        let out = j.to_string_compact();
        assert_eq!(Json::parse(&out).unwrap(), j);
    }

    #[test]
    fn pretty_parses_back() {
        let j = Json::obj(vec![
            ("name", "kinetic".into()),
            ("n", 3u64.into()),
            ("xs", Json::arr([1u64.into(), 2u64.into()])),
        ]);
        let pretty = j.to_string_pretty();
        assert!(pretty.contains('\n'));
        assert_eq!(Json::parse(&pretty).unwrap(), j);
    }

    #[test]
    fn typed_accessors() {
        let j = Json::parse(r#"{"s":"v","n":7,"f":1.5,"b":true}"#).unwrap();
        assert_eq!(j.req_str("s").unwrap(), "v");
        assert_eq!(j.req_u64("n").unwrap(), 7);
        assert_eq!(j.req_f64("f").unwrap(), 1.5);
        assert!(j.opt_bool("b", false));
        assert_eq!(j.opt_f64("missing", 9.0), 9.0);
        assert!(matches!(j.req_str("nope"), Err(JsonError::MissingField(_))));
        assert!(matches!(j.req_u64("f"), Err(JsonError::WrongType(_))));
    }

    #[test]
    fn integer_formatting_has_no_decimal() {
        assert_eq!(Json::Num(5.0).to_string_compact(), "5");
        assert_eq!(Json::Num(5.25).to_string_compact(), "5.25");
    }
}
