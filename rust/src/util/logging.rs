//! Tiny `log`-facade backend with per-run verbosity, used by the CLI and
//! examples. Writes to stderr so experiment tables on stdout stay clean.

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};

use log::{Level, LevelFilter, Log, Metadata, Record};

static VERBOSITY: AtomicU8 = AtomicU8::new(1);

struct StderrLogger;

impl Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        let v = VERBOSITY.load(Ordering::Relaxed);
        let max = match v {
            0 => Level::Error,
            1 => Level::Warn,
            2 => Level::Info,
            3 => Level::Debug,
            _ => Level::Trace,
        };
        metadata.level() <= max
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let mut err = std::io::stderr().lock();
        let _ = writeln!(
            err,
            "[{:<5} {}] {}",
            record.level(),
            record.target(),
            record.args()
        );
    }

    fn flush(&self) {}
}

static LOGGER: StderrLogger = StderrLogger;

/// Installs the logger (idempotent) and sets verbosity 0..=4.
pub fn init(verbosity: u8) {
    VERBOSITY.store(verbosity, Ordering::Relaxed);
    // Ignore AlreadySet errors — tests may init repeatedly.
    let _ = log::set_logger(&LOGGER);
    log::set_max_level(LevelFilter::Trace);
}

/// Current verbosity level.
pub fn verbosity() -> u8 {
    VERBOSITY.load(Ordering::Relaxed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_idempotent_and_sets_verbosity() {
        init(2);
        assert_eq!(verbosity(), 2);
        init(3);
        assert_eq!(verbosity(), 3);
        log::info!("logging smoke test");
    }
}
