//! Structured, level-tagged diagnostics for the CLI and examples.
//!
//! Self-contained on purpose: the crate is dependency-free (see
//! `Cargo.toml`), so this module cannot use the `log` facade crate — an
//! earlier revision did, which made `cargo build` impossible with the
//! empty `[dependencies]` table. Instead, [`log_event!`] routes through
//! this module: a message prints to stderr when its [`Level`] clears the
//! run verbosity, and is *counted* per level when the observation sink is
//! armed (the counts land in the `scenario_<name>_obs.json` summary).
//!
//! The disabled path is a guaranteed no-op: the macro checks
//! [`armed`]/[`enabled`] before building `format_args!`, so with the sink
//! disarmed and the level filtered there is no formatting and no
//! allocation — safe to leave on paths near the simulation hot loop.
//! Counts are process-global; the CLI arms the sink only around a single
//! scenario run, never in library code, so parallel tests stay isolated.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, Ordering};

/// Diagnostic severity. The numeric value is both the count-array slot and
/// the verbosity rank (a level is visible when `index < verbosity`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

impl Level {
    pub const ALL: [Level; 4] = [Level::Error, Level::Warn, Level::Info, Level::Debug];

    pub fn name(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }

    #[inline]
    pub fn index(self) -> usize {
        self as usize
    }
}

static VERBOSITY: AtomicU8 = AtomicU8::new(1);
static SINK_ARMED: AtomicBool = AtomicBool::new(false);
static COUNTS: [AtomicU64; 4] = [
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
];

/// Sets verbosity 0..=4 (0 silent, 1 errors, … 4 debug). Idempotent.
pub fn init(verbosity: u8) {
    VERBOSITY.store(verbosity, Ordering::Relaxed);
}

/// Current verbosity level.
pub fn verbosity() -> u8 {
    VERBOSITY.load(Ordering::Relaxed)
}

/// Would a message at `level` print to stderr right now?
#[inline]
pub fn enabled(level: Level) -> bool {
    (level.index() as u8) < VERBOSITY.load(Ordering::Relaxed)
}

/// Is the observation sink counting emissions?
#[inline]
pub fn armed() -> bool {
    SINK_ARMED.load(Ordering::Relaxed)
}

/// Arms the per-level emission counters (CLI-only, around one run).
pub fn arm_sink() {
    for c in &COUNTS {
        c.store(0, Ordering::Relaxed);
    }
    SINK_ARMED.store(true, Ordering::Relaxed);
}

/// Disarms the sink and returns the per-level counts
/// (`[error, warn, info, debug]`) accumulated since [`arm_sink`].
pub fn drain_sink() -> [u64; 4] {
    SINK_ARMED.store(false, Ordering::Relaxed);
    let mut out = [0u64; 4];
    for (i, c) in COUNTS.iter().enumerate() {
        out[i] = c.swap(0, Ordering::Relaxed);
    }
    out
}

/// Emission backend for [`log_event!`] — call through the macro so the
/// disabled path never reaches here.
pub fn note(level: Level, message: std::fmt::Arguments<'_>) {
    if armed() {
        COUNTS[level.index()].fetch_add(1, Ordering::Relaxed);
    }
    if enabled(level) {
        eprintln!("[{}] {}", level.name(), message);
    }
}

/// Level-tagged structured emission. Checks [`armed`]/[`enabled`] *before*
/// constructing the format arguments, so a filtered call does no
/// formatting and no allocation.
#[macro_export]
macro_rules! log_event {
    ($level:expr, $($arg:tt)*) => {
        if $crate::util::logging::armed() || $crate::util::logging::enabled($level) {
            $crate::util::logging::note($level, format_args!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_idempotent_and_sets_verbosity() {
        init(2);
        assert_eq!(verbosity(), 2);
        init(3);
        assert_eq!(verbosity(), 3);
        init(1);
        assert_eq!(verbosity(), 1);
    }

    #[test]
    fn level_ranks_and_names_are_stable() {
        assert_eq!(Level::Error.index(), 0);
        assert_eq!(Level::Debug.index(), 3);
        let names: Vec<&str> = Level::ALL.iter().map(|l| l.name()).collect();
        assert_eq!(names, vec!["error", "warn", "info", "debug"]);
    }

    #[test]
    fn sink_counts_per_level_and_drains() {
        arm_sink();
        assert!(armed());
        log_event!(Level::Warn, "w {}", 1);
        log_event!(Level::Warn, "w {}", 2);
        log_event!(Level::Debug, "d");
        let counts = drain_sink();
        assert!(!armed());
        assert_eq!(counts[Level::Warn.index()], 2);
        assert_eq!(counts[Level::Debug.index()], 1);
        assert_eq!(counts[Level::Error.index()], 0);
        // Draining resets: a second drain is all zeroes.
        assert_eq!(drain_sink(), [0; 4]);
    }
}
