//! Per-run verbosity for the CLI and examples.
//!
//! Self-contained on purpose: the crate is dependency-free (see
//! `Cargo.toml`), so this module cannot use the `log` facade crate — an
//! earlier revision did, which made `cargo build` impossible with the
//! empty `[dependencies]` table (and nothing ever emitted through the
//! facade anyway, so `--verbose` was a no-op even then). Today the
//! platform prints its diagnostics straight to stderr unconditionally;
//! this knob is where future rate-limited/debug output should check
//! before printing, kept so `kinetic exp --verbose` stays wired.

use std::sync::atomic::{AtomicU8, Ordering};

static VERBOSITY: AtomicU8 = AtomicU8::new(1);

/// Sets verbosity 0..=4 (error..trace). Idempotent.
pub fn init(verbosity: u8) {
    VERBOSITY.store(verbosity, Ordering::Relaxed);
}

/// Current verbosity level.
pub fn verbosity() -> u8 {
    VERBOSITY.load(Ordering::Relaxed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_idempotent_and_sets_verbosity() {
        init(2);
        assert_eq!(verbosity(), 2);
        init(3);
        assert_eq!(verbosity(), 3);
        init(1);
        assert_eq!(verbosity(), 1);
    }
}
