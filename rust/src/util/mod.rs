//! Small self-contained substrates the rest of the crate builds on.
//!
//! The offline crate mirror for this image carries only `xla` and a handful of
//! leaf crates, so the pieces a production service would normally pull from
//! crates.io — JSON, a CLI parser, an RNG with distributions, a statistics /
//! histogram kit, a micro-benchmark harness and a property-testing driver —
//! are implemented here from scratch and unit-tested like any other module.

pub mod bench;
pub mod cli;
pub mod intern;
pub mod json;
pub mod logging;
pub mod nohash;
pub mod prop;
pub mod quantity;
pub mod rng;
pub mod stats;
pub mod table;
