//! Identity hashing for dense sequential ids (RequestId, EventId).
//!
//! The platform's hot maps are keyed by monotonically assigned u64 ids;
//! SipHash showed up at ~6% of the request hot path in `perf` (see
//! EXPERIMENTS.md §Perf). An identity hasher is collision-safe here because
//! the ids are already unique and well-distributed for hashbrown's
//! high-bits bucketing after its multiply-shift finalizer... which hashbrown
//! does NOT apply to `write_u64` — so we mix minimally with a cheap
//! fibonacci multiply instead of full SipHash.

use std::hash::{BuildHasherDefault, Hasher};

/// Hasher for u64-newtype keys: one wrapping multiply (Fibonacci hashing).
#[derive(Default)]
pub struct IdHasher {
    state: u64,
}

impl Hasher for IdHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.state
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        // Only used for the newtype's inner u64 (8 bytes) in practice, but
        // stay correct for arbitrary input.
        for chunk in bytes.chunks(8) {
            let mut buf = [0u8; 8];
            buf[..chunk.len()].copy_from_slice(chunk);
            self.write_u64(u64::from_le_bytes(buf));
        }
    }

    #[inline]
    fn write_u64(&mut self, i: u64) {
        // Fibonacci multiplier spreads sequential ids across the hash space.
        self.state = (self.state ^ i).wrapping_mul(0x9E3779B97F4A7C15);
    }

    #[inline]
    fn write_u32(&mut self, i: u32) {
        self.write_u64(i as u64);
    }

    #[inline]
    fn write_usize(&mut self, i: usize) {
        self.write_u64(i as u64);
    }
}

/// BuildHasher for id-keyed maps/sets.
pub type IdHashBuilder = BuildHasherDefault<IdHasher>;

/// HashMap keyed by sequential-id newtypes.
pub type IdHashMap<K, V> = std::collections::HashMap<K, V, IdHashBuilder>;

/// HashSet keyed by sequential-id newtypes.
pub type IdHashSet<K> = std::collections::HashSet<K, IdHashBuilder>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_basic_ops() {
        let mut m: IdHashMap<u64, &str> = IdHashMap::default();
        for i in 0..1000u64 {
            m.insert(i, "x");
        }
        assert_eq!(m.len(), 1000);
        assert_eq!(m.get(&500), Some(&"x"));
        assert_eq!(m.remove(&500), Some("x"));
        assert_eq!(m.get(&500), None);
    }

    #[test]
    fn sequential_ids_spread() {
        // Fibonacci mixing must not collapse sequential ids into the same
        // high bits (hashbrown uses the top 7 bits for control bytes).
        let b = IdHashBuilder::default();
        use std::hash::BuildHasher;
        let mut tops = std::collections::HashSet::new();
        for i in 0..128u64 {
            let mut h = b.build_hasher();
            h.write_u64(i);
            tops.insert(h.finish() >> 57);
        }
        assert!(tops.len() > 32, "top bits poorly distributed: {}", tops.len());
    }

    #[test]
    fn set_ops() {
        let mut s: IdHashSet<u64> = IdHashSet::default();
        assert!(s.insert(7));
        assert!(!s.insert(7));
        assert!(s.remove(&7));
        assert!(!s.remove(&7));
    }
}
