//! Property-based testing driver (proptest is not in the offline mirror).
//!
//! A `Gen` wraps a seeded [`Rng`](crate::util::rng::Rng) with size-aware
//! generators; [`property`] runs a closure over many generated cases and, on
//! failure, re-runs a bounded shrink loop to report a minimal counterexample
//! seed. Coordinator invariants (routing conservation, CFS work conservation,
//! resize state machine) are tested with this in `rust/tests/`.

use crate::util::rng::Rng;

/// Case generator handed to properties.
pub struct Gen {
    rng: Rng,
    /// Grows with the case index so later cases explore larger structures.
    pub size: usize,
}

impl Gen {
    pub fn new(seed: u64, size: usize) -> Gen {
        Gen {
            rng: Rng::new(seed),
            size,
        }
    }

    pub fn u64(&mut self, lo: u64, hi: u64) -> u64 {
        self.rng.range_u64(lo, hi)
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range_u64(lo as u64, hi as u64) as usize
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    pub fn chance(&mut self, p: f64) -> bool {
        self.rng.chance(p)
    }

    /// Vec with size-scaled length in `[0, max_len]`.
    pub fn vec<T, F: FnMut(&mut Gen) -> T>(&mut self, max_len: usize, mut f: F) -> Vec<T> {
        let cap = max_len.min(self.size.max(1));
        let len = self.usize(0, cap);
        (0..len).map(|_| f(self)).collect()
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        self.rng.choose(xs)
    }

    /// An interesting milliCPU value: the paper's sweep points plus noise.
    pub fn millicpu(&mut self) -> u64 {
        const ANCHORS: [u64; 8] = [1, 5, 50, 100, 200, 500, 1000, 6000];
        if self.bool() {
            *self.rng.choose(&ANCHORS)
        } else {
            self.u64(1, 8000)
        }
    }
}

/// Outcome of a property run.
#[derive(Debug)]
pub struct Failure {
    pub case: usize,
    pub seed: u64,
    pub message: String,
}

/// Runs `cases` generated cases of `prop`. `prop` returns `Err(msg)` to fail.
/// Panics with a reproducible seed on failure.
pub fn property<F>(name: &str, cases: usize, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    let base_seed = env_seed().unwrap_or(0x5EED_0000);
    for case in 0..cases {
        let seed = base_seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let size = 4 + case * 64 / cases.max(1);
        let mut g = Gen::new(seed, size);
        if let Err(message) = prop(&mut g) {
            // One retry at smaller sizes to find a smaller failing case.
            let minimal = shrink_seed(seed, size, &mut prop).unwrap_or((seed, size));
            panic!(
                "property '{name}' failed at case {case}\n  seed={:#x} size={}\n  {message}\n  \
                 reproduce with KINETIC_PROP_SEED={:#x}",
                minimal.0, minimal.1, base_seed
            );
        }
    }
}

/// Tries progressively smaller sizes with the failing seed; returns the
/// smallest (seed, size) that still fails.
fn shrink_seed<F>(seed: u64, size: usize, prop: &mut F) -> Option<(u64, usize)>
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    let mut best = None;
    let mut s = size;
    while s > 1 {
        s /= 2;
        let mut g = Gen::new(seed, s);
        if prop(&mut g).is_err() {
            best = Some((seed, s));
        } else {
            break;
        }
    }
    best
}

fn env_seed() -> Option<u64> {
    std::env::var("KINETIC_PROP_SEED").ok().and_then(|s| {
        let s = s.trim_start_matches("0x");
        u64::from_str_radix(s, 16).ok().or_else(|| s.parse().ok())
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        property("add_commutes", 50, |g| {
            n += 1;
            let a = g.u64(0, 1000);
            let b = g.u64(0, 1000);
            if a + b == b + a {
                Ok(())
            } else {
                Err("math broke".into())
            }
        });
        assert_eq!(n, 50);
    }

    #[test]
    #[should_panic(expected = "property 'always_fails' failed")]
    fn failing_property_panics_with_seed() {
        property("always_fails", 10, |_g| Err("nope".into()));
    }

    #[test]
    fn generators_respect_bounds() {
        property("bounds", 100, |g| {
            let x = g.u64(10, 20);
            if !(10..=20).contains(&x) {
                return Err(format!("u64 out of bounds: {x}"));
            }
            let f = g.f64(-1.0, 1.0);
            if !(-1.0..1.0).contains(&f) {
                return Err(format!("f64 out of bounds: {f}"));
            }
            let v = g.vec(8, |g| g.bool());
            if v.len() > 8 {
                return Err("vec too long".into());
            }
            let m = g.millicpu();
            if !(1..=8000).contains(&m) {
                return Err(format!("millicpu out of bounds: {m}"));
            }
            Ok(())
        });
    }

    #[test]
    fn sizes_grow_over_cases() {
        let mut sizes = Vec::new();
        property("sizes", 32, |g| {
            sizes.push(g.size);
            Ok(())
        });
        assert!(sizes.first().unwrap() < sizes.last().unwrap());
    }
}
