//! Kubernetes resource quantities.
//!
//! CPU is tracked in **milliCPU** (`1000m == 1 CPU`, the unit the paper's
//! experiments sweep over) and memory in bytes. The parser accepts the k8s
//! suffix grammar actually used by the paper's manifests: plain integers,
//! `m` (milli) for CPU, and `Ki/Mi/Gi/K/M/G` for memory.

use std::fmt;
use std::str::FromStr;

/// Errors produced when parsing a resource quantity string.
#[derive(Debug, PartialEq, Eq)]
pub enum QuantityError {
    Empty,
    BadNumber(String),
    BadSuffix(String),
    OutOfRange(String),
}

impl fmt::Display for QuantityError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            QuantityError::Empty => write!(f, "empty quantity"),
            QuantityError::BadNumber(s) => write!(f, "invalid number in quantity: {s}"),
            QuantityError::BadSuffix(s) => write!(f, "unknown suffix in quantity: {s}"),
            QuantityError::OutOfRange(s) => write!(f, "quantity out of range: {s}"),
        }
    }
}

impl std::error::Error for QuantityError {}

/// CPU quantity in milliCPU. `MilliCpu(1000)` is one core.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct MilliCpu(pub u64);

impl MilliCpu {
    pub const ZERO: MilliCpu = MilliCpu(0);
    /// The paper's parked allocation for in-place pods: 1 milliCPU.
    pub const PARKED: MilliCpu = MilliCpu(1);
    /// One full CPU (1000m), the paper's serving allocation.
    pub const ONE_CPU: MilliCpu = MilliCpu(1000);

    pub fn cores(self) -> f64 {
        self.0 as f64 / 1000.0
    }

    pub fn from_cores(cores: f64) -> MilliCpu {
        MilliCpu((cores * 1000.0).round() as u64)
    }

    pub fn saturating_sub(self, other: MilliCpu) -> MilliCpu {
        MilliCpu(self.0.saturating_sub(other.0))
    }

    pub fn min(self, other: MilliCpu) -> MilliCpu {
        MilliCpu(self.0.min(other.0))
    }

    pub fn max(self, other: MilliCpu) -> MilliCpu {
        MilliCpu(self.0.max(other.0))
    }
}

impl fmt::Display for MilliCpu {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.0 % 1000 == 0 && self.0 > 0 {
            write!(f, "{}", self.0 / 1000)
        } else {
            write!(f, "{}m", self.0)
        }
    }
}

impl std::ops::Add for MilliCpu {
    type Output = MilliCpu;
    fn add(self, rhs: MilliCpu) -> MilliCpu {
        MilliCpu(self.0 + rhs.0)
    }
}

impl std::ops::AddAssign for MilliCpu {
    fn add_assign(&mut self, rhs: MilliCpu) {
        self.0 += rhs.0;
    }
}

impl std::ops::Sub for MilliCpu {
    type Output = MilliCpu;
    fn sub(self, rhs: MilliCpu) -> MilliCpu {
        MilliCpu(self.0 - rhs.0)
    }
}

impl std::ops::SubAssign for MilliCpu {
    fn sub_assign(&mut self, rhs: MilliCpu) {
        self.0 -= rhs.0;
    }
}

impl FromStr for MilliCpu {
    type Err = QuantityError;

    /// Parses `"1"`, `"1.5"`, `"1500m"`, `"100m"` into milliCPU.
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let s = s.trim();
        if s.is_empty() {
            return Err(QuantityError::Empty);
        }
        if let Some(num) = s.strip_suffix('m') {
            let v: u64 = num
                .parse()
                .map_err(|_| QuantityError::BadNumber(s.to_string()))?;
            Ok(MilliCpu(v))
        } else {
            let v: f64 = s
                .parse()
                .map_err(|_| QuantityError::BadNumber(s.to_string()))?;
            if !(0.0..=1e9).contains(&v) {
                return Err(QuantityError::OutOfRange(s.to_string()));
            }
            Ok(MilliCpu((v * 1000.0).round() as u64))
        }
    }
}

/// Memory quantity in bytes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct Memory(pub u64);

impl Memory {
    pub const ZERO: Memory = Memory(0);

    pub fn mib(self) -> f64 {
        self.0 as f64 / (1024.0 * 1024.0)
    }

    pub fn from_mib(mib: u64) -> Memory {
        Memory(mib * 1024 * 1024)
    }

    pub fn from_gib(gib: u64) -> Memory {
        Memory(gib * 1024 * 1024 * 1024)
    }

    pub fn saturating_sub(self, other: Memory) -> Memory {
        Memory(self.0.saturating_sub(other.0))
    }
}

impl std::ops::Add for Memory {
    type Output = Memory;
    fn add(self, rhs: Memory) -> Memory {
        Memory(self.0 + rhs.0)
    }
}

impl std::ops::AddAssign for Memory {
    fn add_assign(&mut self, rhs: Memory) {
        self.0 += rhs.0;
    }
}

impl std::ops::SubAssign for Memory {
    fn sub_assign(&mut self, rhs: Memory) {
        self.0 -= rhs.0;
    }
}

impl fmt::Display for Memory {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        const GI: u64 = 1024 * 1024 * 1024;
        const MI: u64 = 1024 * 1024;
        const KI: u64 = 1024;
        if self.0 >= GI && self.0 % GI == 0 {
            write!(f, "{}Gi", self.0 / GI)
        } else if self.0 >= MI && self.0 % MI == 0 {
            write!(f, "{}Mi", self.0 / MI)
        } else if self.0 >= KI && self.0 % KI == 0 {
            write!(f, "{}Ki", self.0 / KI)
        } else {
            write!(f, "{}", self.0)
        }
    }
}

impl FromStr for Memory {
    type Err = QuantityError;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let s = s.trim();
        if s.is_empty() {
            return Err(QuantityError::Empty);
        }
        let (num, mult): (&str, u64) = if let Some(n) = s.strip_suffix("Ki") {
            (n, 1024)
        } else if let Some(n) = s.strip_suffix("Mi") {
            (n, 1024 * 1024)
        } else if let Some(n) = s.strip_suffix("Gi") {
            (n, 1024 * 1024 * 1024)
        } else if let Some(n) = s.strip_suffix('K') {
            (n, 1000)
        } else if let Some(n) = s.strip_suffix('M') {
            (n, 1_000_000)
        } else if let Some(n) = s.strip_suffix('G') {
            (n, 1_000_000_000)
        } else if s.chars().all(|c| c.is_ascii_digit()) {
            (s, 1)
        } else {
            return Err(QuantityError::BadSuffix(s.to_string()));
        };
        let v: u64 = num
            .parse()
            .map_err(|_| QuantityError::BadNumber(s.to_string()))?;
        v.checked_mul(mult)
            .map(Memory)
            .ok_or_else(|| QuantityError::OutOfRange(s.to_string()))
    }
}

/// A CPU+memory resource vector, the unit of pod requests/limits and node
/// allocatable capacity.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default, Hash)]
pub struct Resources {
    pub cpu: MilliCpu,
    pub memory: Memory,
}

impl Resources {
    pub const ZERO: Resources = Resources {
        cpu: MilliCpu::ZERO,
        memory: Memory::ZERO,
    };

    pub fn new(cpu: MilliCpu, memory: Memory) -> Resources {
        Resources { cpu, memory }
    }

    pub fn cpu_m(cpu_m: u64) -> Resources {
        Resources {
            cpu: MilliCpu(cpu_m),
            memory: Memory::ZERO,
        }
    }

    /// True when `self` fits inside `capacity` on both axes.
    pub fn fits_in(&self, capacity: &Resources) -> bool {
        self.cpu <= capacity.cpu && self.memory <= capacity.memory
    }

    pub fn saturating_sub(&self, other: &Resources) -> Resources {
        Resources {
            cpu: self.cpu.saturating_sub(other.cpu),
            memory: self.memory.saturating_sub(other.memory),
        }
    }
}

impl std::ops::Add for Resources {
    type Output = Resources;
    fn add(self, rhs: Resources) -> Resources {
        Resources {
            cpu: self.cpu + rhs.cpu,
            memory: self.memory + rhs.memory,
        }
    }
}

impl std::ops::AddAssign for Resources {
    fn add_assign(&mut self, rhs: Resources) {
        self.cpu += rhs.cpu;
        self.memory += rhs.memory;
    }
}

impl std::ops::SubAssign for Resources {
    fn sub_assign(&mut self, rhs: Resources) {
        self.cpu -= rhs.cpu;
        self.memory -= rhs.memory;
    }
}

impl fmt::Display for Resources {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "cpu={} mem={}", self.cpu, self.memory)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_millicpu() {
        assert_eq!("100m".parse::<MilliCpu>().unwrap(), MilliCpu(100));
        assert_eq!("1m".parse::<MilliCpu>().unwrap(), MilliCpu(1));
        assert_eq!("1".parse::<MilliCpu>().unwrap(), MilliCpu(1000));
        assert_eq!("1.5".parse::<MilliCpu>().unwrap(), MilliCpu(1500));
        assert_eq!("6".parse::<MilliCpu>().unwrap(), MilliCpu(6000));
    }

    #[test]
    fn parse_millicpu_errors() {
        assert!("".parse::<MilliCpu>().is_err());
        assert!("abc".parse::<MilliCpu>().is_err());
        assert!("12q".parse::<MilliCpu>().is_err());
        assert!("-5".parse::<MilliCpu>().is_err());
    }

    #[test]
    fn display_millicpu_round_trips() {
        for s in ["100m", "1m", "999m", "2", "6"] {
            let q: MilliCpu = s.parse().unwrap();
            assert_eq!(q.to_string(), s);
        }
    }

    #[test]
    fn cores_conversion() {
        assert_eq!(MilliCpu(1500).cores(), 1.5);
        assert_eq!(MilliCpu::from_cores(0.25), MilliCpu(250));
    }

    #[test]
    fn parse_memory() {
        assert_eq!("10Gi".parse::<Memory>().unwrap(), Memory::from_gib(10));
        assert_eq!("512Mi".parse::<Memory>().unwrap(), Memory::from_mib(512));
        assert_eq!("1024".parse::<Memory>().unwrap(), Memory(1024));
        assert_eq!("4K".parse::<Memory>().unwrap(), Memory(4000));
    }

    #[test]
    fn parse_memory_errors() {
        assert!("".parse::<Memory>().is_err());
        assert!("10Qi".parse::<Memory>().is_err());
        assert!("xGi".parse::<Memory>().is_err());
    }

    #[test]
    fn display_memory() {
        assert_eq!(Memory::from_gib(10).to_string(), "10Gi");
        assert_eq!(Memory::from_mib(512).to_string(), "512Mi");
        assert_eq!(Memory(1000).to_string(), "1000");
    }

    #[test]
    fn resources_fit() {
        let node = Resources::new(MilliCpu(8000), Memory::from_gib(10));
        let pod = Resources::new(MilliCpu(1000), Memory::from_mib(256));
        assert!(pod.fits_in(&node));
        assert!(!node.fits_in(&pod));
    }

    #[test]
    fn resources_arithmetic() {
        let mut a = Resources::new(MilliCpu(500), Memory::from_mib(100));
        a += Resources::new(MilliCpu(250), Memory::from_mib(50));
        assert_eq!(a.cpu, MilliCpu(750));
        a -= Resources::new(MilliCpu(750), Memory::from_mib(150));
        assert_eq!(a, Resources::ZERO);
    }

    #[test]
    fn saturating_sub_clamps() {
        let a = Resources::cpu_m(100);
        let b = Resources::cpu_m(500);
        assert_eq!(a.saturating_sub(&b).cpu, MilliCpu::ZERO);
    }
}
