//! Deterministic PRNG + the distributions the simulator needs.
//!
//! Every stochastic component in the simulation (resize-latency noise, arrival
//! processes, trace generation, property-test case generation) draws from a
//! seeded [`Rng`] so experiment runs are exactly reproducible. The generator
//! is xoshiro256**, seeded via SplitMix64 — the standard small-state generator
//! with full 2^256-1 period; no crates.io dependency.

/// xoshiro256** seeded through SplitMix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Creates a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Rng {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derives an independent child generator (for per-component streams).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)`. Panics if `n == 0`.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "Rng::below(0)");
        // Lemire's multiply-shift rejection method.
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    #[inline]
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform float in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponential with rate `lambda` (mean `1/lambda`) — Poisson
    /// inter-arrival times for the open-loop load generator.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        let u = 1.0 - self.f64(); // avoid ln(0)
        -u.ln() / lambda
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast here).
    pub fn normal(&mut self, mean: f64, std_dev: f64) -> f64 {
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        let z = (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        mean + std_dev * z
    }

    /// Log-normal parameterized by the mean/σ of the *resulting* distribution
    /// (not of the underlying normal) — used by the resize-latency model where
    /// the paper reports means and standard deviations directly.
    pub fn lognormal_mean_std(&mut self, mean: f64, std_dev: f64) -> f64 {
        assert!(mean > 0.0);
        if std_dev <= 0.0 {
            return mean;
        }
        let cv2 = (std_dev / mean).powi(2);
        let sigma2 = (1.0 + cv2).ln();
        let mu = mean.ln() - sigma2 / 2.0;
        (mu + sigma2.sqrt() * self.normal(0.0, 1.0)).exp()
    }

    /// Zipf-distributed rank in `[0, n)` with exponent `s` — function
    /// popularity in the Azure-style trace generator (Shahrad et al. observe
    /// heavily skewed invocation counts).
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        debug_assert!(n > 0);
        // Inverse-CDF over precomputable harmonic weights would need state;
        // for the trace sizes used here (n <= 4096) a linear scan is fine.
        let mut total = 0.0;
        for k in 1..=n {
            total += 1.0 / (k as f64).powf(s);
        }
        let mut u = self.f64() * total;
        for k in 1..=n {
            u -= 1.0 / (k as f64).powf(s);
            if u <= 0.0 {
                return k - 1;
            }
        }
        n - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Picks a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty());
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_bounded_and_covers() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let x = r.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn exponential_mean_close() {
        let mut r = Rng::new(11);
        let lambda = 2.0;
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.exponential(lambda)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments_close() {
        let mut r = Rng::new(13);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal(5.0, 2.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 5.0).abs() < 0.05, "mean={mean}");
        assert!((var.sqrt() - 2.0).abs() < 0.05, "std={}", var.sqrt());
    }

    #[test]
    fn lognormal_mean_std_close() {
        let mut r = Rng::new(17);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.lognormal_mean_std(56.44, 8.53)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 56.44).abs() < 0.5, "mean={mean}");
        assert!((var.sqrt() - 8.53).abs() < 0.5, "std={}", var.sqrt());
        assert!(xs.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn lognormal_zero_std_degenerates() {
        let mut r = Rng::new(19);
        assert_eq!(r.lognormal_mean_std(10.0, 0.0), 10.0);
    }

    #[test]
    fn zipf_is_skewed() {
        let mut r = Rng::new(23);
        let mut counts = [0usize; 16];
        for _ in 0..20_000 {
            counts[r.zipf(16, 1.2)] += 1;
        }
        assert!(counts[0] > counts[4]);
        assert!(counts[4] > counts[15]);
    }

    #[test]
    fn shuffle_preserves_elements() {
        let mut r = Rng::new(29);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(31);
        let mut a = root.fork();
        let mut b = root.fork();
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
