//! Latency statistics: running summaries, exact-percentile samples, and
//! HDR-style log-bucketed histograms for high-volume hot paths.

use std::fmt;

/// Running mean / variance via Welford's algorithm plus min/max.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Summary {
        Summary {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn record(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    /// Sample standard deviation.
    pub fn std_dev(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).sqrt()
        }
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }

    pub fn merge(&mut self, other: &Summary) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let delta = other.mean - self.mean;
        let mean = self.mean + delta * other.n as f64 / n as f64;
        let m2 = self.m2
            + other.m2
            + delta * delta * (self.n as f64 * other.n as f64) / n as f64;
        self.n = n;
        self.mean = mean;
        self.m2 = m2;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

impl fmt::Display for Summary {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "n={} mean={:.2} sd={:.2} min={:.2} max={:.2}",
            self.n,
            self.mean(),
            self.std_dev(),
            self.min(),
            self.max()
        )
    }
}

/// Exact-percentile reservoir: stores every sample. Fine for experiment-scale
/// counts (≤ a few million); the hot path uses [`LogHistogram`] instead.
#[derive(Debug, Clone, Default)]
pub struct Samples {
    xs: Vec<f64>,
    sorted: bool,
}

impl Samples {
    pub fn new() -> Samples {
        Samples {
            xs: Vec::new(),
            sorted: true,
        }
    }

    pub fn record(&mut self, x: f64) {
        self.xs.push(x);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            0.0
        } else {
            self.xs.iter().sum::<f64>() / self.xs.len() as f64
        }
    }

    pub fn std_dev(&self) -> f64 {
        if self.xs.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / (self.xs.len() - 1) as f64)
            .sqrt()
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
    }

    /// Percentile by linear interpolation; `q` in `[0, 100]`.
    pub fn percentile(&mut self, q: f64) -> f64 {
        if self.xs.is_empty() {
            return 0.0;
        }
        self.ensure_sorted();
        let rank = q / 100.0 * (self.xs.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            self.xs[lo]
        } else {
            let w = rank - lo as f64;
            self.xs[lo] * (1.0 - w) + self.xs[hi] * w
        }
    }

    pub fn median(&mut self) -> f64 {
        self.percentile(50.0)
    }

    pub fn min(&mut self) -> f64 {
        if self.xs.is_empty() {
            return 0.0;
        }
        self.ensure_sorted();
        self.xs[0]
    }

    pub fn max(&mut self) -> f64 {
        if self.xs.is_empty() {
            return 0.0;
        }
        self.ensure_sorted();
        *self.xs.last().unwrap()
    }

    pub fn values(&self) -> &[f64] {
        &self.xs
    }
}

/// Log-bucketed histogram (HdrHistogram-flavoured): ~2.5% relative error,
/// constant memory, O(1) record. Units are whatever the caller records —
/// the platform uses microseconds.
#[derive(Debug, Clone)]
pub struct LogHistogram {
    /// buckets[i] counts values in [lo_i, lo_i * growth).
    buckets: Vec<u64>,
    zero_count: u64,
    growth: f64,
    inv_log_growth: f64,
    count: u64,
    sum: f64,
    max: f64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        LogHistogram::new()
    }
}

impl LogHistogram {
    /// 5% bucket growth, covers [1, ~1e13] in 640 buckets.
    pub fn new() -> LogHistogram {
        let growth = 1.05f64;
        LogHistogram {
            buckets: vec![0; 640],
            zero_count: 0,
            growth,
            inv_log_growth: 1.0 / growth.ln(),
            count: 0,
            sum: 0.0,
            max: 0.0,
        }
    }

    #[inline]
    fn index(&self, x: f64) -> usize {
        // x >= 1 here.
        let idx = (x.ln() * self.inv_log_growth) as usize;
        idx.min(self.buckets.len() - 1)
    }

    #[inline]
    pub fn record(&mut self, x: f64) {
        self.count += 1;
        self.sum += x;
        if x > self.max {
            self.max = x;
        }
        if x < 1.0 {
            self.zero_count += 1;
        } else {
            let i = self.index(x);
            self.buckets[i] += 1;
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Approximate percentile (bucket midpoint in log space).
    pub fn percentile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (q / 100.0 * self.count as f64).ceil() as u64;
        let mut acc = self.zero_count;
        if acc >= target {
            return 0.0;
        }
        for (i, &c) in self.buckets.iter().enumerate() {
            acc += c;
            if acc >= target {
                let lo = self.growth.powi(i as i32);
                let hi = lo * self.growth;
                return (lo * hi).sqrt(); // geometric midpoint
            }
        }
        self.max
    }

    pub fn merge(&mut self, other: &LogHistogram) {
        assert_eq!(self.buckets.len(), other.buckets.len());
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += b;
        }
        self.zero_count += other.zero_count;
        self.count += other.count;
        self.sum += other.sum;
        self.max = self.max.max(other.max);
    }
}

/// Streaming twin of [`Samples`]: count/sum/min/max plus a fixed-bucket
/// linear histogram. O(1) record, constant memory, mergeable — the shape
/// the observability artifacts aggregate with, where a full reservoir per
/// sampled dimension would defeat the bounded-memory discipline.
#[derive(Debug, Clone, PartialEq)]
pub struct StreamStats {
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
    /// `buckets[i]` counts values in `[i·width, (i+1)·width)`; the last
    /// bucket absorbs everything beyond the covered range.
    buckets: Vec<u64>,
    width: f64,
}

impl Default for StreamStats {
    /// 64 × 250 ms buckets — covers request latencies up to 16 s linearly.
    fn default() -> StreamStats {
        StreamStats::new(64, 250.0)
    }
}

impl StreamStats {
    pub fn new(buckets: usize, width: f64) -> StreamStats {
        assert!(buckets > 0 && width > 0.0);
        StreamStats {
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            buckets: vec![0; buckets],
            width,
        }
    }

    #[inline]
    pub fn record(&mut self, x: f64) {
        self.count += 1;
        self.sum += x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        let i = if x <= 0.0 {
            0
        } else {
            ((x / self.width) as usize).min(self.buckets.len() - 1)
        };
        self.buckets[i] += 1;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    pub fn min(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.max
        }
    }

    pub fn bucket_width(&self) -> f64 {
        self.width
    }

    pub fn bucket_counts(&self) -> &[u64] {
        &self.buckets
    }

    pub fn merge(&mut self, other: &StreamStats) {
        assert_eq!(self.buckets.len(), other.buckets.len());
        assert_eq!(self.width, other.width);
        if other.count == 0 {
            return;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += b;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_moments() {
        let mut s = Summary::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.record(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.std_dev() - 2.138089935299395).abs() < 1e-9);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn summary_merge_equals_concat() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0 + 20.0).collect();
        let mut whole = Summary::new();
        for &x in &xs {
            whole.record(x);
        }
        let mut a = Summary::new();
        let mut b = Summary::new();
        for (i, &x) in xs.iter().enumerate() {
            if i % 2 == 0 {
                a.record(x)
            } else {
                b.record(x)
            }
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert!((a.mean() - whole.mean()).abs() < 1e-9);
        assert!((a.std_dev() - whole.std_dev()).abs() < 1e-9);
    }

    #[test]
    fn empty_summary_is_zeroes() {
        let s = Summary::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.std_dev(), 0.0);
    }

    #[test]
    fn samples_percentiles() {
        let mut s = Samples::new();
        for i in 1..=100 {
            s.record(i as f64);
        }
        assert_eq!(s.median(), 50.5);
        assert!((s.percentile(99.0) - 99.01).abs() < 0.02);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 100.0);
    }

    #[test]
    fn samples_single_value() {
        let mut s = Samples::new();
        s.record(42.0);
        assert_eq!(s.median(), 42.0);
        assert_eq!(s.percentile(0.0), 42.0);
        assert_eq!(s.percentile(100.0), 42.0);
    }

    #[test]
    fn log_histogram_percentile_accuracy() {
        let mut h = LogHistogram::new();
        for i in 1..=10_000u64 {
            h.record(i as f64);
        }
        let p50 = h.percentile(50.0);
        assert!((p50 - 5000.0).abs() / 5000.0 < 0.06, "p50={p50}");
        let p99 = h.percentile(99.0);
        assert!((p99 - 9900.0).abs() / 9900.0 < 0.06, "p99={p99}");
        assert_eq!(h.count(), 10_000);
        assert!((h.mean() - 5000.5).abs() < 0.1);
    }

    #[test]
    fn log_histogram_merge() {
        let mut a = LogHistogram::new();
        let mut b = LogHistogram::new();
        for i in 1..=500u64 {
            a.record(i as f64);
            b.record((i + 500) as f64);
        }
        a.merge(&b);
        assert_eq!(a.count(), 1000);
        let p50 = a.percentile(50.0);
        assert!((p50 - 500.0).abs() / 500.0 < 0.08, "p50={p50}");
    }

    #[test]
    fn stream_stats_tracks_moments_and_buckets() {
        let mut s = StreamStats::new(4, 10.0);
        for x in [0.0, 5.0, 15.0, 25.0, 1000.0] {
            s.record(x);
        }
        assert_eq!(s.count(), 5);
        assert_eq!(s.sum(), 1045.0);
        assert_eq!(s.min(), 0.0);
        assert_eq!(s.max(), 1000.0);
        assert_eq!(s.mean(), 209.0);
        // [0,10) ×2, [10,20) ×1, [20,30) ×1, overflow ×1.
        assert_eq!(s.bucket_counts(), &[2, 1, 1, 1]);
    }

    #[test]
    fn stream_stats_merge_equals_concat() {
        let xs: Vec<f64> = (0..50).map(|i| i as f64 * 3.7).collect();
        let mut whole = StreamStats::new(8, 20.0);
        let mut a = StreamStats::new(8, 20.0);
        let mut b = StreamStats::new(8, 20.0);
        for (i, &x) in xs.iter().enumerate() {
            whole.record(x);
            if i % 2 == 0 {
                a.record(x)
            } else {
                b.record(x)
            }
        }
        a.merge(&b);
        assert_eq!(a, whole);
    }

    #[test]
    fn empty_stream_stats_is_zeroes() {
        let s = StreamStats::default();
        assert_eq!(s.count(), 0);
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.min(), 0.0);
        assert_eq!(s.max(), 0.0);
    }

    #[test]
    fn log_histogram_sub_one_values() {
        let mut h = LogHistogram::new();
        h.record(0.5);
        h.record(0.1);
        h.record(100.0);
        assert_eq!(h.count(), 3);
        assert_eq!(h.percentile(50.0), 0.0); // sub-1 values collapse to bucket 0
    }
}
