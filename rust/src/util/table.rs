//! ASCII / markdown table rendering for experiment reports — the bench
//! harness prints the same rows the paper's tables and figures report.

/// Column alignment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Align {
    Left,
    Right,
}

/// A simple table builder.
#[derive(Debug, Clone)]
pub struct Table {
    headers: Vec<String>,
    aligns: Vec<Align>,
    rows: Vec<Vec<String>>,
    title: Option<String>,
}

impl Table {
    pub fn new<S: Into<String>>(headers: Vec<S>) -> Table {
        let headers: Vec<String> = headers.into_iter().map(Into::into).collect();
        let aligns = headers
            .iter()
            .enumerate()
            .map(|(i, _)| if i == 0 { Align::Left } else { Align::Right })
            .collect();
        Table {
            headers,
            aligns,
            rows: Vec::new(),
            title: None,
        }
    }

    pub fn title<S: Into<String>>(mut self, t: S) -> Table {
        self.title = Some(t.into());
        self
    }

    pub fn align(mut self, col: usize, a: Align) -> Table {
        if col < self.aligns.len() {
            self.aligns[col] = a;
        }
        self
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Table {
        let mut cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        cells.resize(self.headers.len(), String::new());
        self.rows.push(cells);
        self
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.chars().count());
            }
        }
        w
    }

    fn fmt_cell(cell: &str, width: usize, align: Align) -> String {
        let pad = width.saturating_sub(cell.chars().count());
        match align {
            Align::Left => format!("{}{}", cell, " ".repeat(pad)),
            Align::Right => format!("{}{}", " ".repeat(pad), cell),
        }
    }

    /// Box-drawing ASCII rendering for terminal output.
    pub fn to_ascii(&self) -> String {
        let w = self.widths();
        let sep = |l: &str, m: &str, r: &str| -> String {
            let mut s = String::from(l);
            for (i, width) in w.iter().enumerate() {
                s.push_str(&"-".repeat(width + 2));
                s.push_str(if i + 1 == w.len() { r } else { m });
            }
            s.push('\n');
            s
        };
        let mut out = String::new();
        if let Some(t) = &self.title {
            out.push_str(t);
            out.push('\n');
        }
        out.push_str(&sep("+", "+", "+"));
        out.push('|');
        for (i, h) in self.headers.iter().enumerate() {
            out.push_str(&format!(" {} |", Self::fmt_cell(h, w[i], Align::Left)));
        }
        out.push('\n');
        out.push_str(&sep("+", "+", "+"));
        for row in &self.rows {
            out.push('|');
            for (i, c) in row.iter().enumerate() {
                out.push_str(&format!(" {} |", Self::fmt_cell(c, w[i], self.aligns[i])));
            }
            out.push('\n');
        }
        out.push_str(&sep("+", "+", "+"));
        out
    }

    /// GitHub-flavoured markdown rendering for EXPERIMENTS.md.
    pub fn to_markdown(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        if let Some(t) = &self.title {
            out.push_str(&format!("**{t}**\n\n"));
        }
        out.push('|');
        for (i, h) in self.headers.iter().enumerate() {
            out.push_str(&format!(" {} |", Self::fmt_cell(h, w[i], Align::Left)));
        }
        out.push('\n');
        out.push('|');
        for (i, a) in self.aligns.iter().enumerate() {
            let dashes = "-".repeat(w[i].max(3));
            match a {
                Align::Left => out.push_str(&format!(" {dashes} |")),
                Align::Right => out.push_str(&format!(" {}: |", &dashes[..dashes.len() - 1])),
            }
        }
        out.push('\n');
        for row in &self.rows {
            out.push('|');
            for (i, c) in row.iter().enumerate() {
                out.push_str(&format!(" {} |", Self::fmt_cell(c, w[i], self.aligns[i])));
            }
            out.push('\n');
        }
        out
    }

    /// CSV rendering for downstream plotting.
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| -> String {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = self
            .headers
            .iter()
            .map(|h| esc(h))
            .collect::<Vec<_>>()
            .join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Formats a float with a sensible number of digits for latency tables.
pub fn fmt_ms(x: f64) -> String {
    if x >= 1000.0 {
        format!("{x:.1}")
    } else if x >= 10.0 {
        format!("{x:.2}")
    } else {
        format!("{x:.3}")
    }
}

/// Formats a ratio like the paper's Table 3 (two decimals).
pub fn fmt_ratio(x: f64) -> String {
    format!("{x:.2}")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Table {
        let mut t = Table::new(vec!["Function", "Cold", "Warm"]).title("Table 3");
        t.row(vec!["helloworld", "286.99", "3.87"]);
        t.row(vec!["cpu", "2.00", "1.13"]);
        t
    }

    #[test]
    fn ascii_contains_all_cells() {
        let s = sample().to_ascii();
        for needle in ["Table 3", "helloworld", "286.99", "1.13", "Function"] {
            assert!(s.contains(needle), "missing {needle} in\n{s}");
        }
        // All data lines share the same width.
        let widths: Vec<usize> = s
            .lines()
            .filter(|l| l.starts_with('|') || l.starts_with('+'))
            .map(|l| l.chars().count())
            .collect();
        assert!(widths.windows(2).all(|w| w[0] == w[1]), "{widths:?}");
    }

    #[test]
    fn markdown_shape() {
        let s = sample().to_markdown();
        let lines: Vec<&str> = s.lines().collect();
        assert!(lines[0].starts_with("**Table 3**"));
        assert!(lines[3].contains("---")); // title, blank, header, separator
        assert_eq!(lines.len(), 2 + 2 + 2); // title+blank, header+sep, 2 rows
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["x,y", "he said \"hi\""]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"he said \"\"hi\"\"\""));
    }

    #[test]
    fn short_rows_padded() {
        let mut t = Table::new(vec!["a", "b", "c"]);
        t.row(vec!["only"]);
        assert!(t.to_ascii().contains("only"));
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_ms(5.312), "5.312");
        assert_eq!(fmt_ms(56.44), "56.44");
        assert_eq!(fmt_ms(2465.18), "2465.2");
        assert_eq!(fmt_ratio(18.149), "18.15");
    }
}
