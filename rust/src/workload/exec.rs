//! Request progress integration across allocation changes.
//!
//! Under the in-place policy a request starts executing at the parked
//! allocation (1 m) and speeds up when the resize lands; under any policy,
//! concurrent requests share the container's allocation. [`Execution`]
//! tracks the *normalized remaining work* of one request and integrates it
//! piecewise across those regime changes:
//!
//! progress rate at allocation `a` = `1 / (cpu_frac · 1000/a + (1 − cpu_frac))`
//! in units of "default (1-CPU) runtimes per unit time", so a request is done
//! when accumulated progress reaches `runtime_1cpu_ms`.

use crate::simclock::SimTime;
use crate::util::quantity::MilliCpu;
use crate::workload::registry::WorkloadProfile;

/// One in-flight request's progress state.
#[derive(Debug, Clone)]
pub struct Execution {
    /// Remaining work in default-runtime milliseconds.
    remaining_ms: f64,
    cpu_frac: f64,
    min_useful_cpu: MilliCpu,
    /// Virtual time at which `remaining_ms` was last integrated.
    last_update: SimTime,
}

impl Execution {
    /// Starts an execution for `profile` at time `now`.
    pub fn start(profile: &WorkloadProfile, now: SimTime) -> Execution {
        Execution {
            remaining_ms: profile.runtime_1cpu_ms,
            cpu_frac: profile.cpu_frac,
            min_useful_cpu: profile.min_useful_cpu,
            last_update: now,
        }
    }

    /// Stretch factor at allocation `a`: wall-ms per default-runtime-ms.
    fn stretch(&self, alloc: MilliCpu) -> f64 {
        if alloc < self.min_useful_cpu {
            // Effectively stalled: interpreter heartbeat only. Finite but
            // enormous, so EDTs stay schedulable.
            return 1000.0 / (alloc.0.max(1) as f64) * 10.0;
        }
        let a = alloc.0 as f64;
        self.cpu_frac * 1000.0 / a + (1.0 - self.cpu_frac)
    }

    /// Integrates progress from `last_update` to `now` at allocation
    /// `alloc` (the allocation that was in force over that interval).
    pub fn advance(&mut self, now: SimTime, alloc: MilliCpu) {
        debug_assert!(now >= self.last_update);
        let dt_ms = (now - self.last_update).as_millis_f64();
        let progressed = dt_ms / self.stretch(alloc);
        self.remaining_ms = (self.remaining_ms - progressed).max(0.0);
        self.last_update = now;
    }

    /// Completion ETA from `now` if allocation `alloc` stays in force.
    pub fn eta(&self, alloc: MilliCpu) -> SimTime {
        SimTime::from_millis_f64(self.remaining_ms * self.stretch(alloc))
    }

    pub fn done(&self) -> bool {
        self.remaining_ms <= 1e-9
    }

    pub fn remaining_default_ms(&self) -> f64 {
        self.remaining_ms
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::registry::{WorkloadKind, WorkloadProfile};

    fn profile(kind: WorkloadKind) -> WorkloadProfile {
        WorkloadProfile::paper(kind)
    }

    #[test]
    fn constant_allocation_matches_closed_form() {
        let p = profile(WorkloadKind::Cpu);
        for alloc in [MilliCpu(250), MilliCpu(1000), MilliCpu(4000)] {
            let e = Execution::start(&p, SimTime::ZERO);
            let eta = e.eta(alloc).as_millis_f64();
            let want = p.runtime_at(alloc);
            assert!((eta - want).abs() < 0.5, "alloc={alloc} eta={eta} want={want}");
        }
    }

    #[test]
    fn piecewise_integration_sums_correctly() {
        // Run the cpu workload 100 ms at 1 CPU, then finish at 2 CPU.
        let p = profile(WorkloadKind::Cpu);
        let mut e = Execution::start(&p, SimTime::ZERO);
        e.advance(SimTime::from_millis(100), MilliCpu(1000));
        // 100 default-ms consumed (stretch≈1 at 1 CPU for cpu_frac≈1).
        let rem = e.remaining_default_ms();
        assert!((rem - (p.runtime_1cpu_ms - 100.0 / e.stretch(MilliCpu(1000)))).abs() < 1e-6);
        let eta2 = e.eta(MilliCpu(2000)).as_millis_f64();
        // Remaining work at 2 CPU takes ~rem*stretch(2000).
        assert!((eta2 - rem * e.stretch(MilliCpu(2000))).abs() < 1e-6);
    }

    #[test]
    fn in_place_dead_window_costs_the_window() {
        // The in-place activation scenario: 56 ms at 1 m, then 1 CPU.
        let p = profile(WorkloadKind::Cpu);
        let mut e = Execution::start(&p, SimTime::ZERO);
        e.advance(SimTime::from_millis(56), MilliCpu(1));
        // Essentially no progress happened.
        assert!(p.runtime_1cpu_ms - e.remaining_default_ms() < 0.1);
        let total = 56.0 + e.eta(MilliCpu(1000)).as_millis_f64();
        // Total ≈ runtime + dead window.
        assert!((total - (p.runtime_1cpu_ms + 56.0)).abs() < 0.5, "total={total}");
    }

    #[test]
    fn io_bound_work_survives_low_allocation() {
        let p = profile(WorkloadKind::Io);
        let e = Execution::start(&p, SimTime::ZERO);
        // Even at 10m, io work (62% wall-bound) finishes in bounded time:
        // stretch = 0.38*100 + 0.62 ≈ 38.6.
        let eta = e.eta(MilliCpu(10)).as_millis_f64();
        assert!(eta < 40.0 * p.runtime_1cpu_ms, "eta={eta}");
    }

    #[test]
    fn completion_detection() {
        let p = profile(WorkloadKind::HelloWorld);
        let mut e = Execution::start(&p, SimTime::ZERO);
        let eta = e.eta(MilliCpu(1000));
        e.advance(eta, MilliCpu(1000));
        assert!(e.done());
        // Advancing past completion stays done, no underflow.
        e.advance(eta + SimTime::from_millis(10), MilliCpu(1000));
        assert!(e.done());
        assert_eq!(e.remaining_default_ms(), 0.0);
    }

    #[test]
    fn stalled_allocation_is_finite_but_huge() {
        let p = profile(WorkloadKind::Cpu);
        let e = Execution::start(&p, SimTime::ZERO);
        let eta_1m = e.eta(MilliCpu(1)).as_secs_f64();
        assert!(eta_1m > 3600.0, "parked cpu work must be ~stalled");
        assert!(eta_1m.is_finite());
    }
}
