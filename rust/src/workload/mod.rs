//! Function workloads.
//!
//! The paper evaluates six Python functions (Table 2): `helloworld`, `cpu`
//! (a "complicate math problem"), `io` (open a file n times), and three
//! video-watermark jobs from SeBS at 10 s / 1 m / 10 m of input. This module
//! models each as a [`WorkloadProfile`] — calibrated default runtime at
//! 1 CPU, CPU-bound fraction, image/runtime-init properties — plus an
//! [`Execution`] progress integrator that answers the question the in-place
//! policy hinges on: *how much work gets done while the allocation is
//! changing under the request?*
//!
//! The `cpu` and `video` workloads also carry a real compute path: their
//! inner loop is an AOT-compiled JAX/Pallas kernel executed through
//! [`crate::runtime`] in the end-to-end example, with these profiles'
//! service times calibrated from Table 2.

pub mod exec;
pub mod registry;

pub use exec::Execution;
pub use registry::{WorkloadKind, WorkloadProfile};
