//! The workload catalog, calibrated to the paper's Table 2.

use crate::util::quantity::MilliCpu;

/// The six paper workloads (plus a parameterizable custom slot).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum WorkloadKind {
    HelloWorld,
    Cpu,
    Io,
    Video10s,
    Video1m,
    Video10m,
}

impl WorkloadKind {
    pub const ALL: [WorkloadKind; 6] = [
        WorkloadKind::HelloWorld,
        WorkloadKind::Cpu,
        WorkloadKind::Io,
        WorkloadKind::Video10s,
        WorkloadKind::Video1m,
        WorkloadKind::Video10m,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            WorkloadKind::HelloWorld => "helloworld",
            WorkloadKind::Cpu => "cpu",
            WorkloadKind::Io => "io",
            WorkloadKind::Video10s => "videos-10s",
            WorkloadKind::Video1m => "videos-1m",
            WorkloadKind::Video10m => "videos-10m",
        }
    }
}

impl std::str::FromStr for WorkloadKind {
    type Err = String;

    /// Parses the [`WorkloadKind::name`] spelling (scenario specs name
    /// workload mixes by these strings).
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let lower = s.to_ascii_lowercase();
        WorkloadKind::ALL
            .iter()
            .find(|k| k.name() == lower)
            .copied()
            .ok_or_else(|| {
                let known: Vec<&str> = WorkloadKind::ALL.iter().map(|k| k.name()).collect();
                format!("unknown workload '{s}' (known: {})", known.join("|"))
            })
    }
}

/// Static execution profile of a function.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadProfile {
    pub kind: WorkloadKind,
    pub name: String,
    /// Table 2: runtime at 1 CPU, milliseconds.
    pub runtime_1cpu_ms: f64,
    /// Fraction of the runtime that scales with CPU allocation; the rest is
    /// I/O / wall-clock bound (file opens, codec reads) and does not.
    pub cpu_frac: f64,
    /// Container image and its (compressed) size for cold pulls.
    pub image: String,
    pub image_mb: f64,
    /// Language-runtime boot + imports, ms (part of the cold start).
    pub runtime_init_ms: f64,
    /// Below this allocation the function makes essentially no progress
    /// (interpreter heartbeat, GC, page faults dominate).
    pub min_useful_cpu: MilliCpu,
    /// AOT artifact executed on the real-compute path (e2e example);
    /// `None` for trivial/io-only workloads.
    pub artifact: Option<String>,
}

impl WorkloadProfile {
    /// Table 2 calibration.
    pub fn paper(kind: WorkloadKind) -> WorkloadProfile {
        // (runtime_ms, cpu_frac, image_mb, init_ms, artifact)
        let (runtime, cpu_frac, image_mb, init_ms, artifact): (f64, f64, f64, f64, Option<&str>) =
            match kind {
                // "return the helloworld string" — all overhead, tiny CPU.
                WorkloadKind::HelloWorld => (5.31, 0.85, 98.0, 410.0, None),
                // "complicate math problem" — pure CPU.
                WorkloadKind::Cpu => (2465.18, 0.99, 112.0, 450.0, Some("compute")),
                // "open file n times" — syscall/page-cache heavy.
                WorkloadKind::Io => (2258.22, 0.38, 105.0, 430.0, None),
                // ffmpeg watermark over N frames: decode is I/O-ish, the
                // blend is CPU.
                WorkloadKind::Video10s => (1659.03, 0.85, 310.0, 780.0, Some("watermark")),
                WorkloadKind::Video1m => (13888.03, 0.85, 310.0, 780.0, Some("watermark")),
                WorkloadKind::Video10m => (119028.34, 0.85, 310.0, 780.0, Some("watermark")),
            };
        WorkloadProfile {
            kind,
            name: kind.name().to_string(),
            runtime_1cpu_ms: runtime,
            cpu_frac,
            image: format!("kinetic/{}:v1", kind.name()),
            image_mb,
            runtime_init_ms: init_ms,
            min_useful_cpu: MilliCpu(2),
            artifact: artifact.map(str::to_string),
        }
    }

    /// All six Table-2 profiles.
    pub fn paper_catalog() -> Vec<WorkloadProfile> {
        WorkloadKind::ALL.iter().map(|&k| Self::paper(k)).collect()
    }

    /// Expected runtime at a *fixed* allocation, ms — the simple closed form
    /// the progress integrator generalizes.
    pub fn runtime_at(&self, alloc: MilliCpu) -> f64 {
        let a = alloc.0.max(1) as f64;
        self.runtime_1cpu_ms * (self.cpu_frac * 1000.0 / a + (1.0 - self.cpu_frac))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_runtimes_match() {
        let expect = [
            (WorkloadKind::HelloWorld, 5.31),
            (WorkloadKind::Cpu, 2465.18),
            (WorkloadKind::Io, 2258.22),
            (WorkloadKind::Video10s, 1659.03),
            (WorkloadKind::Video1m, 13888.03),
            (WorkloadKind::Video10m, 119028.34),
        ];
        for (kind, ms) in expect {
            let p = WorkloadProfile::paper(kind);
            assert_eq!(p.runtime_1cpu_ms, ms);
            // At exactly 1 CPU the closed form returns the Table-2 number.
            assert!((p.runtime_at(MilliCpu(1000)) - ms).abs() < 1e-9);
        }
    }

    #[test]
    fn catalog_has_six_unique_names() {
        let cat = WorkloadProfile::paper_catalog();
        assert_eq!(cat.len(), 6);
        let mut names: Vec<_> = cat.iter().map(|p| p.name.clone()).collect();
        names.dedup();
        assert_eq!(names.len(), 6);
    }

    #[test]
    fn cpu_bound_scales_io_bound_doesnt() {
        let cpu = WorkloadProfile::paper(WorkloadKind::Cpu);
        let io = WorkloadProfile::paper(WorkloadKind::Io);
        // Doubling CPU nearly halves the cpu workload...
        let cpu_speedup = cpu.runtime_at(MilliCpu(1000)) / cpu.runtime_at(MilliCpu(2000));
        assert!(cpu_speedup > 1.85, "{cpu_speedup}");
        // ...but barely moves the io workload.
        let io_speedup = io.runtime_at(MilliCpu(1000)) / io.runtime_at(MilliCpu(2000));
        assert!(io_speedup < 1.35, "{io_speedup}");
    }

    #[test]
    fn parked_allocation_is_catastrophic_for_cpu_work() {
        let cpu = WorkloadProfile::paper(WorkloadKind::Cpu);
        // At 1m the cpu workload would take ~1000× longer — why the in-place
        // policy must scale up before real work happens.
        assert!(cpu.runtime_at(MilliCpu(1)) > 500.0 * cpu.runtime_at(MilliCpu(1000)));
    }

    #[test]
    fn video_artifacts_wired() {
        assert_eq!(
            WorkloadProfile::paper(WorkloadKind::Video10s).artifact.as_deref(),
            Some("watermark")
        );
        assert_eq!(WorkloadProfile::paper(WorkloadKind::HelloWorld).artifact, None);
    }
}
