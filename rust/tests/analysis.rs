//! End-to-end lock on the measurement pipeline: parallel `kinetic run`
//! must be byte-identical to serial, and `kinetic analyze` must produce
//! the paper-style speedup table (cold-policy baseline, ratio column)
//! from a real scenario run.

use kinetic::analysis::{self, AnalysisReport};
use kinetic::policy::Policy;
use kinetic::scenario::preset;
use kinetic::scenario::{ScenarioEngine, ScenarioReport, ScenarioSpec};
use kinetic::util::json::Json;

/// The acceptance-criteria test: `--threads 4` emits a ScenarioReport
/// byte-identical to `--threads 1` on the `smoke` preset — not just
/// structurally equal, the exact JSON text that lands on disk.
#[test]
fn smoke_report_is_byte_identical_across_thread_counts() {
    let spec = preset::by_name("smoke").expect("smoke preset exists");
    let serial = ScenarioEngine::run_with_threads(&spec, 1).unwrap();
    let parallel = ScenarioEngine::run_with_threads(&spec, 4).unwrap();
    let serial_text = serial.to_json().to_string_pretty();
    let parallel_text = parallel.to_json().to_string_pretty();
    assert!(
        serial_text == parallel_text,
        "parallel report text diverged from serial"
    );
    assert_eq!(serial_text.as_bytes(), parallel_text.as_bytes());
}

/// `kinetic analyze` on a smoke run: a markdown speedup table with the
/// cold-policy baseline and the paper-style `×` ratio column.
#[test]
fn analyze_smoke_emits_the_paper_style_speedup_table() {
    let spec = preset::by_name("smoke").unwrap();
    let report = ScenarioEngine::run(&spec).unwrap();
    let a = AnalysisReport::from_scenario(&report, Policy::Cold);
    assert_eq!(a.rows.len(), 3); // one aggregated cell per §3 policy

    let md = analysis::render(&a.speedup_table(), analysis::Format::Markdown);
    assert!(md.contains("× vs cold (mean)"), "{md}");
    assert!(md.contains("× vs cold (p99)"), "{md}");
    // The baseline's own ratio is exactly 1.00×; every §3 policy appears
    // (the smoke preset intentionally stays the paper triple).
    assert!(md.contains("1.00×"), "{md}");
    for p in Policy::PAPER {
        assert!(md.contains(p.name()), "missing {} in\n{md}", p.name());
    }
    // Smoke completes work under every policy, so every ratio is defined.
    for row in &a.rows {
        assert!(row.group.has_latency(), "{:?}", row.group.key);
        assert!(row.mean_ratio.is_some(), "{:?}", row.group.key);
        let r = row.mean_ratio.unwrap();
        assert!(r.is_finite() && r > 0.0, "{r}");
    }
    // The emitted AnalysisReport JSON validates and round-trips.
    let j = a.to_json();
    AnalysisReport::validate(&j).unwrap();
    let back = AnalysisReport::from_json(
        &Json::parse(&j.to_string_pretty()).unwrap(),
    )
    .unwrap();
    assert_eq!(back, a);
}

/// Comparing a report against itself is the degenerate regression check:
/// zero deltas everywhere, exit path "no regressions".
#[test]
fn self_compare_has_no_regressions() {
    let spec = preset::by_name("smoke").unwrap();
    let report = ScenarioEngine::run(&spec).unwrap();
    let groups = analysis::aggregate(&report.rows);
    let cmp = analysis::compare(&groups, &groups, 1.0);
    assert_eq!(cmp.deltas.len(), groups.len());
    assert!(!cmp.has_regressions());
    assert!(!cmp.keys_mismatch());
    for d in &cmp.deltas {
        assert_eq!(d.mean_pct, Some(0.0));
        assert_eq!(d.p99_pct, Some(0.0));
    }
}

/// A spec comparing the forecast-driven policies against the §3 triple:
/// the full grid (5 policies × reps × a forecast sweep axis).
fn predictive_spec() -> ScenarioSpec {
    ScenarioSpec::parse(
        r#"{
        "name": "predictive",
        "workload": {"type": "synthetic", "services": 4,
                     "rate_per_service": 0.2, "horizon_s": 40},
        "topology": {"kind": "uniform", "nodes": 2},
        "policies": ["cold", "warm", "in-place", "pooled", "predictive-inplace"],
        "forecast": {"pool_size": 2, "horizon_ms": 2000},
        "reps": 2,
        "sweep": [{"param": "forecast_horizon_ms", "values": [1000, 2000]}]
    }"#,
    )
    .unwrap()
}

/// The predictive acceptance pin: `pooled` and `predictive-inplace` run
/// end-to-end from a ScenarioSpec, the report stays byte-identical across
/// `--threads` counts, and both policies appear in the `kinetic analyze`
/// speedup table against the `cold` baseline with defined ratios.
#[test]
fn predictive_report_is_byte_identical_and_analyzes_vs_cold() {
    let spec = predictive_spec();
    let serial = ScenarioEngine::run_with_threads(&spec, 1).unwrap();
    // 2 variants × 1 routing × 5 policies × 2 reps.
    assert_eq!(serial.rows.len(), 20);
    let parallel = ScenarioEngine::run_with_threads(&spec, 4).unwrap();
    assert_eq!(
        serial.to_json().to_string_pretty().as_bytes(),
        parallel.to_json().to_string_pretty().as_bytes(),
        "predictive report must not depend on the worker count"
    );
    for r in &serial.rows {
        assert_eq!(r.failed, 0, "{:?}", r.policy);
        assert!(r.completed > 0, "{:?}", r.policy);
    }

    let a = AnalysisReport::from_scenario(&serial, Policy::Cold);
    let md = analysis::render(&a.speedup_table(), analysis::Format::Markdown);
    assert!(md.contains("× vs cold (mean)"), "{md}");
    for p in Policy::ALL {
        assert!(md.contains(p.name()), "missing {} in\n{md}", p.name());
    }
    for row in &a.rows {
        assert!(row.mean_ratio.is_some(), "{:?}", row.group.key);
        let r = row.mean_ratio.unwrap();
        assert!(r.is_finite() && r > 0.0, "{r}");
    }
    // The warm pool serves from pre-warmed pods: faster than cold.
    for row in a.rows.iter().filter(|s| s.group.key.policy == Policy::Pooled) {
        assert!(
            row.mean_ratio.unwrap() > 1.0,
            "pooled must beat the cold baseline: {:?}",
            row.mean_ratio
        );
    }
    // The hit-rate signal is observable end-to-end: predictive cells
    // carry speculation counters, everything else reports zero.
    for row in &a.rows {
        match row.group.key.policy {
            Policy::PredictiveInPlace => assert!(
                row.group.speculative_resizes > 0,
                "predictive cells must record speculation: {:?}",
                row.group.key
            ),
            _ => assert_eq!(
                (row.group.speculative_resizes, row.group.mispredictions),
                (0, 0),
                "{:?}",
                row.group.key
            ),
        }
    }
}

/// The saved ScenarioReport (what `kinetic run` writes) loads back and
/// analyzes — the exact artifact path CI's analyze-smoke step exercises.
#[test]
fn saved_report_round_trips_through_analyze() {
    let dir = std::env::temp_dir().join(format!("kinetic-analyze-{}", std::process::id()));
    let spec = preset::by_name("smoke").unwrap();
    let report = ScenarioEngine::run_with_threads(&spec, 2).unwrap();
    let path = report.save(&dir).unwrap();
    let loaded = ScenarioReport::load(&path).unwrap();
    assert_eq!(loaded, report);
    let a = AnalysisReport::from_scenario(&loaded, Policy::Cold);
    let saved = a.save(&dir).unwrap();
    let text = std::fs::read_to_string(&saved).unwrap();
    AnalysisReport::validate(&Json::parse(&text).unwrap()).unwrap();
    std::fs::remove_dir_all(&dir).ok();
}
