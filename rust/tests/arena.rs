//! ABA regression suite for the generational pod slab
//! (`cluster::arena`): stale `PodHandle`s — freed slots, reused indices,
//! bumped generations — must be rejected everywhere a `PodId` can outlive
//! its pod. Randomized create/free churn pins the slab itself; the
//! platform-level tests walk the two paths that actually retire pods
//! out from under outstanding ids: crash eviction (PR 7 faults) and
//! cross-shard reschedule (PR 8 sharded runtime).
//!
//! The HashMap audit rides here too: the slab replaced the last *iterated*
//! `HashMap` in the hot state (`Cluster.pods`); the surviving hash
//! containers (`Node.image_cache`, the request table) are lookup-only and
//! can never leak iteration order into a report — `tests/interning.rs`
//! pins that with seed-repro byte-identity.

use kinetic::cluster::arena::{PodHandle, PodSlab};
use kinetic::cluster::pod::{PodId, PodSpec};
use kinetic::cluster::topology::Topology;
use kinetic::coordinator::event::Event;
use kinetic::coordinator::platform::Simulation;
use kinetic::policy::Policy;
use kinetic::simclock::SimTime;
use kinetic::util::prop::{property, Gen};
use kinetic::util::quantity::{Memory, MilliCpu, Resources};
use kinetic::workload::registry::{WorkloadKind, WorkloadProfile};

fn spec() -> PodSpec {
    PodSpec::single(
        "fn",
        "img",
        Resources::new(MilliCpu(100), Memory::from_mib(64)),
        Resources::new(MilliCpu(1000), Memory::from_mib(128)),
    )
}

// ------------------------------------------------------------- slab props

/// Randomized alloc/free churn: live ids always resolve, every retired id
/// is rejected forever (even after its slot is reused), double frees are
/// no-ops, and `len`/iteration stay consistent throughout.
#[test]
fn prop_stale_handles_rejected_under_churn() {
    property("stale_handles_rejected_under_churn", 120, |g: &mut Gen| {
        let mut slab = PodSlab::new();
        let mut live: Vec<PodId> = Vec::new();
        let mut dead: Vec<PodId> = Vec::new();
        let ops = g.usize(10, 120);
        for _ in 0..ops {
            if live.is_empty() || g.bool() {
                let id = slab.alloc(spec());
                if live.contains(&id) || dead.contains(&id) {
                    return Err(format!("id {id:?} reissued — ABA"));
                }
                live.push(id);
            } else {
                let victim = live.remove(g.usize(0, live.len() - 1));
                let pod = slab.remove(victim).ok_or("live remove failed")?;
                if pod.id != victim {
                    return Err(format!("removed {:?} via {victim:?}", pod.id));
                }
                dead.push(victim);
            }
            // Occasionally poke a dead id: reads and frees must both miss.
            if !dead.is_empty() && g.bool() {
                let stale = dead[g.usize(0, dead.len() - 1)];
                if slab.get(stale).is_some() {
                    return Err(format!("stale {stale:?} resolved"));
                }
                if slab.remove(stale).is_some() {
                    return Err(format!("stale {stale:?} freed twice"));
                }
            }
            if slab.len() != live.len() {
                return Err(format!("len {} != live {}", slab.len(), live.len()));
            }
        }
        for &id in &live {
            let pod = slab.get(id).ok_or_else(|| format!("live {id:?} lost"))?;
            if pod.id != id {
                return Err(format!("live {id:?} resolved to {:?}", pod.id));
            }
        }
        for &id in &dead {
            if slab.get(id).is_some() {
                return Err(format!("dead {id:?} resurrected"));
            }
        }
        // Iteration covers exactly the live set, in slot order.
        let seen: Vec<PodId> = slab.iter().map(|p| p.id).collect();
        if seen.len() != live.len() {
            return Err(format!("iter saw {} of {} live", seen.len(), live.len()));
        }
        let indices: Vec<u32> = seen.iter().map(|&i| PodHandle::from_id(i).index).collect();
        let mut sorted = indices.clone();
        sorted.sort_unstable();
        if indices != sorted {
            return Err("iteration not slot-ordered".to_string());
        }
        Ok(())
    });
}

/// The packed-id encoding is a bijection: any (index, generation) pair
/// survives `to_id`/`from_id`, and distinct pairs give distinct ids.
#[test]
fn prop_handle_packing_roundtrips() {
    property("handle_packing_roundtrips", 200, |g: &mut Gen| {
        let a = PodHandle {
            index: g.u64(0, u32::MAX as u64) as u32,
            generation: g.u64(0, u32::MAX as u64) as u32,
        };
        let b = PodHandle {
            index: g.u64(0, u32::MAX as u64) as u32,
            generation: g.u64(0, u32::MAX as u64) as u32,
        };
        if PodHandle::from_id(a.to_id()) != a {
            return Err(format!("{a:?} did not round-trip"));
        }
        if a != b && a.to_id() == b.to_id() {
            return Err(format!("{a:?} and {b:?} collide"));
        }
        Ok(())
    });
}

// ------------------------------------------------- platform retire paths

/// Scale-to-zero teardown retires the pod's slot; the captured id must go
/// stale and stay stale after the slot is reused by the next cold start.
#[test]
fn teardown_and_reuse_keep_old_id_stale() {
    let mut sim = Simulation::paper(11);
    sim.deploy(
        "fn",
        WorkloadProfile::paper(WorkloadKind::HelloWorld),
        Policy::Cold,
    );
    sim.run();
    sim.submit("fn");
    // Capture the cold-started pod's id before the 6 s stable window can
    // tear it down (helloworld cold start lands well under 4 s).
    sim.run_until(sim.now() + SimTime::from_secs(4));
    let first = sim.world.services["fn"].pods[0].pod;
    assert!(sim.world.cluster.pod(first).is_some(), "pod live mid-run");
    sim.run(); // drain the idle check + teardown: the slot retires
    assert_eq!(sim.world.services["fn"].pods.len(), 0, "cold pod torn down");
    assert!(
        sim.world.cluster.pod(first).is_none(),
        "retired id must not resolve"
    );
    // Next cold start reuses the slot (LIFO free list) under a bumped
    // generation: fresh id, same index, old id still rejected.
    sim.submit("fn");
    sim.run_until(sim.now() + SimTime::from_secs(4));
    let second = sim.world.services["fn"].pods[0].pod;
    assert_ne!(first, second, "reused slot must mint a distinct id");
    assert_eq!(
        PodHandle::from_id(first).index,
        PodHandle::from_id(second).index,
        "LIFO reuse returns the same slot"
    );
    assert!(sim.world.cluster.pod(first).is_none(), "ABA: old id aliased");
    assert_eq!(sim.world.cluster.pod(second).unwrap().id, second);
}

/// The PR 7 crash-evict path: a node crash force-evicts every resident
/// pod. Ids captured before the crash must read as gone even after
/// recovery reuses their slots for replacement pods.
#[test]
fn crash_evict_invalidates_captured_ids() {
    let mut sim = Simulation::fleet(Topology::uniform_paper(2), 5);
    sim.deploy(
        "fn",
        WorkloadProfile::paper(WorkloadKind::HelloWorld),
        Policy::Warm,
    );
    sim.run();
    let doomed = sim.world.services["fn"].pods[0].pod;
    let node = sim.world.services["fn"].pods[0].node.expect("pod placed");
    assert!(sim.world.cluster.pod(doomed).is_some());

    sim.engine
        .schedule_at(sim.now() + SimTime::from_secs(1), Event::NodeCrash { node });
    sim.run();

    assert!(
        sim.world.metrics.pods_evicted >= 1,
        "crash must evict the resident pod"
    );
    assert!(
        sim.world.cluster.pod(doomed).is_none(),
        "evicted id must not resolve after recovery reuses the slot"
    );
    // Recovery replaced the pod on the surviving node with a fresh handle.
    let svc = &sim.world.services["fn"];
    assert_eq!(svc.ready_pods(), 1, "replacement came up");
    let replacement = svc.pods[0].pod;
    assert_ne!(replacement, doomed);
    assert_eq!(sim.world.cluster.pod(replacement).unwrap().id, replacement);
    assert_ne!(svc.pods[0].node, Some(node), "replaced off the dead node");
}

/// The PR 8 cross-shard reschedule path: an `XShardReschedule` delivery
/// starts replacement pods through the same slab; the new handles resolve,
/// and the event is a no-op for interned-but-never-deployed services
/// (the guard the sharded runtime relies on at window barriers).
#[test]
fn xshard_reschedule_mints_valid_handles() {
    let mut sim = Simulation::fleet(Topology::uniform_paper(2), 9);
    sim.deploy(
        "fn",
        WorkloadProfile::paper(WorkloadKind::HelloWorld),
        Policy::Warm,
    );
    sim.run();
    let before: Vec<_> = sim.world.services["fn"].pods.iter().map(|p| p.pod).collect();
    let svc_id = sim.world.services.id_of("fn").expect("deployed service interned");
    sim.engine.schedule_at(
        sim.now() + SimTime::from_millis(10),
        Event::XShardReschedule {
            service: svc_id,
            pods: 2,
        },
    );
    // Capture before the stable window can park the surplus replicas.
    sim.run_until(sim.now() + SimTime::from_secs(4));
    assert_eq!(sim.world.metrics.pods_rescheduled, 2);
    let after: Vec<_> = sim.world.services["fn"].pods.iter().map(|p| p.pod).collect();
    assert_eq!(after.len(), before.len() + 2);
    for &id in &after {
        assert_eq!(sim.world.cluster.pod(id).unwrap().id, id);
    }

    // Interned-but-undeployed target: the delivery must no-op, not panic.
    let ghost = sim.world.intern_service("ghost");
    let rescheduled = sim.world.metrics.pods_rescheduled;
    sim.engine.schedule_at(
        sim.now() + SimTime::from_millis(10),
        Event::XShardReschedule {
            service: ghost,
            pods: 3,
        },
    );
    sim.run();
    assert_eq!(sim.world.metrics.pods_rescheduled, rescheduled);
    assert!(sim.world.services.get(ghost).is_none());
}
