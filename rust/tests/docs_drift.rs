//! Pins `docs/SCENARIO_SCHEMA.md` to the generator behind
//! `kinetic schema --markdown`: the committed reference must match what
//! the code would emit today. Refresh an intentionally changed schema
//! with `KINETIC_BLESS=1 cargo test --test docs_drift`.

use kinetic::scenario::schema_doc;

fn doc_path() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../docs/SCENARIO_SCHEMA.md")
}

#[test]
fn scenario_schema_doc_matches_the_generator() {
    let want = schema_doc::markdown();
    let path = doc_path();
    if std::env::var("KINETIC_BLESS").is_ok() {
        std::fs::write(&path, &want).expect("write blessed schema doc");
        eprintln!("blessed {}", path.display());
        return;
    }
    let got = std::fs::read_to_string(&path).unwrap_or_else(|e| {
        panic!(
            "{} is missing ({e}); generate it with \
             `KINETIC_BLESS=1 cargo test --test docs_drift`",
            path.display()
        )
    });
    if got != want {
        // Point at the first diverging line instead of dumping both docs.
        let line = got
            .lines()
            .zip(want.lines())
            .position(|(g, w)| g != w)
            .map(|i| i + 1)
            .unwrap_or_else(|| got.lines().count().min(want.lines().count()) + 1);
        panic!(
            "docs/SCENARIO_SCHEMA.md is stale (first difference at line {line}); \
             regenerate with `KINETIC_BLESS=1 cargo test --test docs_drift` \
             or `kinetic schema --markdown > docs/SCENARIO_SCHEMA.md`.\n\
             committed: {:?}\ngenerated: {:?}",
            got.lines().nth(line - 1).unwrap_or("<eof>"),
            want.lines().nth(line - 1).unwrap_or("<eof>"),
        );
    }
}
