//! Differential property tests: the calendar-queue engine against the
//! retained BinaryHeap oracle ([`kinetic::simclock::oracle`]).
//!
//! The oracle's observable firing order is the specification — randomized
//! schedules with cancellations and in-handler chains must replay on the
//! new core with identical `(time, tag)` sequences and `processed` counts.
//! The one place the engines deliberately *differ* is `pending()` after a
//! stale cancel: the oracle leaks a tombstone forever, the new core is
//! exact ([`pending_exactness_regression`]).

use kinetic::simclock::oracle::OracleEngine;
use kinetic::simclock::{Engine, SimTime, World};
use kinetic::util::rng::Rng;

/// What both engines record: `(virtual nanos at fire, tag)`.
type Fired = Vec<(u64, u32)>;

/// Chained events get their parent's tag plus this offset.
const CHAIN_TAG: u32 = 1_000_000;

#[derive(Default)]
struct Log {
    fired: Fired,
}

struct Ev {
    tag: u32,
    /// Schedule a follow-up this many nanos after firing.
    chain: Option<u64>,
}

impl World for Log {
    type Event = Ev;

    fn handle(&mut self, ev: Ev, eng: &mut Engine<Self>) {
        self.fired.push((eng.now().as_nanos(), ev.tag));
        if let Some(d) = ev.chain {
            eng.schedule_in(
                SimTime::from_nanos(d),
                Ev {
                    tag: ev.tag + CHAIN_TAG,
                    chain: None,
                },
            );
        }
    }
}

#[derive(Default)]
struct OLog {
    fired: Fired,
}

/// Oracle-side leaf handler: log and stop (the chained event's shape).
fn oracle_leaf(tag: u32) -> impl FnOnce(&mut OLog, &mut OracleEngine<OLog>) {
    move |w, eng| w.fired.push((eng.now().as_nanos(), tag))
}

/// Oracle-side handler mirroring [`Ev`]: log, then maybe chain once.
fn oracle_handler(tag: u32, chain: Option<u64>) -> impl FnOnce(&mut OLog, &mut OracleEngine<OLog>) {
    move |w, eng| {
        w.fired.push((eng.now().as_nanos(), tag));
        if let Some(d) = chain {
            eng.schedule_in(SimTime::from_nanos(d), oracle_leaf(tag + CHAIN_TAG));
        }
    }
}

/// One pre-run operation of a randomized schedule script.
enum Op {
    Schedule { at: u64, tag: u32, chain: Option<u64> },
    /// Cancel the `nth` schedule op issued so far (possibly repeatedly).
    Cancel { nth: usize },
}

/// Seeded script: ~25% cancels, ~30% of events chain a follow-up.
fn script(seed: u64, n: usize) -> Vec<Op> {
    let mut rng = Rng::new(seed);
    let mut ops = Vec::new();
    let mut scheduled = 0u64;
    for i in 0..n {
        if scheduled > 0 && rng.chance(0.25) {
            ops.push(Op::Cancel {
                nth: rng.below(scheduled) as usize,
            });
        } else {
            ops.push(Op::Schedule {
                at: rng.below(5_000_000),
                tag: i as u32,
                chain: if rng.chance(0.3) {
                    Some(rng.below(200_000) + 1)
                } else {
                    None
                },
            });
            scheduled += 1;
        }
    }
    ops
}

/// Replays `ops` on the new engine. `step_ns = Some(d)` drains via
/// repeated `run_until(now + d)` instead of one `run`.
fn run_new(ops: &[Op], step_ns: Option<u64>) -> (Fired, u64, u64) {
    let mut eng: Engine<Log> = Engine::new();
    let mut w = Log::default();
    let mut ids = Vec::new();
    for op in ops {
        match op {
            Op::Schedule { at, tag, chain } => {
                let s = eng.schedule_at(
                    SimTime::from_nanos(*at),
                    Ev {
                        tag: *tag,
                        chain: *chain,
                    },
                );
                ids.push(s.id);
            }
            Op::Cancel { nth } => eng.cancel(ids[*nth]),
        }
    }
    let mut processed = 0;
    match step_ns {
        None => processed += eng.run(&mut w),
        Some(step) => {
            while eng.pending() > 0 {
                let deadline = eng.now() + SimTime::from_nanos(step);
                processed += eng.run_until(&mut w, deadline);
            }
        }
    }
    (w.fired, processed, eng.now().as_nanos())
}

/// Replays `ops` on the oracle, same drive modes.
fn run_oracle(ops: &[Op], step_ns: Option<u64>) -> (Fired, u64, u64) {
    let mut eng: OracleEngine<OLog> = OracleEngine::new();
    let mut w = OLog::default();
    let mut ids = Vec::new();
    for op in ops {
        match op {
            Op::Schedule { at, tag, chain } => {
                let (tag, chain) = (*tag, *chain);
                let s = eng.schedule_at(SimTime::from_nanos(*at), oracle_handler(tag, chain));
                ids.push(s.id);
            }
            Op::Cancel { nth } => eng.cancel(ids[*nth]),
        }
    }
    let mut processed = 0;
    match step_ns {
        None => processed += eng.run(&mut w),
        Some(step) => {
            // Pre-run cancels only ever tombstone still-queued entries, so
            // the oracle's approximate `pending()` is exact here too.
            while eng.pending() > 0 {
                let deadline = eng.now() + SimTime::from_nanos(step);
                processed += eng.run_until(&mut w, deadline);
            }
        }
    }
    (w.fired, processed, eng.now().as_nanos())
}

/// The tentpole proof: identical event order and processed counts over
/// randomized schedules with cancellations and chains.
#[test]
fn randomized_schedules_match_the_oracle() {
    for seed in 0..20u64 {
        let ops = script(seed, 300);
        let (new_fired, new_n, new_now) = run_new(&ops, None);
        let (old_fired, old_n, old_now) = run_oracle(&ops, None);
        assert_eq!(new_fired, old_fired, "firing order diverged (seed {seed})");
        assert_eq!(new_n, old_n, "processed diverged (seed {seed})");
        assert_eq!(new_now, old_now, "final clock diverged (seed {seed})");
    }
}

/// `run_until` in fixed increments is the same trajectory as one `run`,
/// on both cores — the deduplicated drain path has no mode skew.
#[test]
fn stepped_run_until_matches_run_and_oracle() {
    for seed in 100..110u64 {
        let ops = script(seed, 200);
        let plain = run_new(&ops, None);
        let stepped = run_new(&ops, Some(250_000));
        assert_eq!(plain.0, stepped.0, "stepped firing order (seed {seed})");
        assert_eq!(plain.1, stepped.1, "stepped processed (seed {seed})");
        let oracle_stepped = run_oracle(&ops, Some(250_000));
        assert_eq!(stepped, oracle_stepped, "stepped oracle diff (seed {seed})");
    }
}

/// Same-time insertions fire in insertion order — on both cores.
#[test]
fn same_time_ties_fire_in_insertion_order_on_both() {
    let ops: Vec<Op> = (0..200)
        .map(|i| Op::Schedule {
            at: 7_777,
            tag: i,
            chain: None,
        })
        .collect();
    let expect: Fired = (0..200).map(|i| (7_777, i)).collect();
    assert_eq!(run_new(&ops, None).0, expect);
    assert_eq!(run_oracle(&ops, None).0, expect);
}

/// Cancel-then-reschedule chains: every even-numbered schedule is
/// cancelled immediately; the survivors fire in order, identically.
#[test]
fn cancel_then_reschedule_chains_are_deterministic_on_both() {
    let mut ops = Vec::new();
    let mut nth = 0;
    for i in 0..100u32 {
        ops.push(Op::Schedule {
            at: 1_000,
            tag: i,
            chain: None,
        });
        if i % 2 == 0 {
            ops.push(Op::Cancel { nth });
        }
        nth += 1;
    }
    let expect: Fired = (0..100).filter(|i| i % 2 == 1).map(|i| (1_000, i)).collect();
    assert_eq!(run_new(&ops, None).0, expect);
    assert_eq!(run_oracle(&ops, None).0, expect);
}

/// Double-cancelling the same event is a no-op on both cores.
#[test]
fn double_cancel_is_idempotent_on_both() {
    let ops = vec![
        Op::Schedule { at: 10, tag: 0, chain: None },
        Op::Schedule { at: 20, tag: 1, chain: None },
        Op::Cancel { nth: 0 },
        Op::Cancel { nth: 0 },
    ];
    let expect: Fired = vec![(20, 1)];
    let (fired, n, _) = run_new(&ops, None);
    assert_eq!((fired, n), (expect.clone(), 1));
    let (fired, n, _) = run_oracle(&ops, None);
    assert_eq!((fired, n), (expect, 1));
}

/// The one sanctioned divergence: after cancelling an already-fired id,
/// the oracle's `pending()` under-counts forever (the tombstone leak);
/// the slot-based core stays exact.
#[test]
fn pending_exactness_regression_documents_the_oracle_leak() {
    // Oracle: the leak.
    let mut eng: OracleEngine<OLog> = OracleEngine::new();
    let mut w = OLog::default();
    let fired = eng.schedule_at(SimTime::from_nanos(1), oracle_leaf(0));
    eng.run(&mut w);
    eng.cancel(fired.id); // stale — leaks a tombstone
    eng.schedule_at(SimTime::from_nanos(2), oracle_leaf(1));
    assert_eq!(eng.pending(), 0, "the oracle under-counts (documented wart)");

    // New core: the fix.
    let mut eng: Engine<Log> = Engine::new();
    let mut w = Log::default();
    let fired = eng.schedule_at(SimTime::from_nanos(1), Ev { tag: 0, chain: None });
    eng.run(&mut w);
    eng.cancel(fired.id); // stale — true no-op
    eng.schedule_at(SimTime::from_nanos(2), Ev { tag: 1, chain: None });
    assert_eq!(eng.pending(), 1, "the slot-based core is exact");
    assert_eq!(eng.run(&mut w), 1, "the pending event still fires");
}

/// Same seed, same trajectory — twice.
#[test]
fn replays_are_deterministic() {
    let ops = script(424242, 400);
    assert_eq!(run_new(&ops, None), run_new(&ops, None));
}
