//! Integration locks for the fault-injection subsystem:
//!
//! 1. Fault runs are deterministic — `--threads 4` emits a report
//!    byte-identical to `--threads 1` even with crashes, stragglers and
//!    probabilistic resize failures in play.
//! 2. Specs WITHOUT a `faults` section keep emitting the exact pre-fault
//!    v2 document: same schema version, same row key set, no fault
//!    counters anywhere — old baselines stay byte-comparable.
//! 3. The committed `node_crash.json` study shows crash recovery
//!    end-to-end: evictions, reschedules and the analyze fault columns.

use std::path::PathBuf;

use kinetic::analysis::{self, AnalysisReport};
use kinetic::policy::Policy;
use kinetic::scenario::preset;
use kinetic::scenario::{ScenarioEngine, ScenarioSpec};

fn scenarios_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../examples/scenarios")
}

/// A compact spec exercising every fault process at once: a mid-run node
/// crash with requeue recovery, a straggler window, global startup
/// inflation, a probabilistic resize-failure draw, and a sweep over the
/// failure probability (two variants × 3 policies × 2 reps = 12 rows).
fn crash_spec() -> ScenarioSpec {
    ScenarioSpec::parse(
        r#"{
        "name": "crash-det",
        "workload": {"type": "synthetic", "services": 4,
                     "rate_per_service": 0.4, "horizon_s": 45},
        "topology": {"kind": "uniform", "nodes": 3},
        "policies": ["cold", "warm", "in-place"],
        "reps": 2,
        "faults": {
            "node_crashes": [{"node": 2, "at_s": 8, "down_s": 12}],
            "crash_requests": "requeue",
            "stragglers": [{"node": 0, "from_s": 0, "until_s": 20,
                            "startup_factor": 3.0}],
            "startup_inflation": 1.5,
            "resize_failure_p": 0.1
        },
        "sweep": [{"param": "resize_failure_p", "values": [0.0, 0.25]}]
    }"#,
    )
    .unwrap()
}

/// The acceptance-criteria property: fault schedules ride the same typed
/// event queue as everything else, so the worker count must not change a
/// single byte of the report — crashes, stragglers and seeded
/// resize-failure draws included.
#[test]
fn fault_reports_are_byte_identical_across_thread_counts() {
    let spec = crash_spec();
    let serial = ScenarioEngine::run_with_threads(&spec, 1).unwrap();
    assert_eq!(serial.rows.len(), 12); // 2 variants × 3 policies × 2 reps
    let parallel = ScenarioEngine::run_with_threads(&spec, 4).unwrap();
    assert_eq!(
        serial.to_json().to_string_pretty().as_bytes(),
        parallel.to_json().to_string_pretty().as_bytes(),
        "fault-injection report must not depend on the worker count"
    );

    // The document upgrades to the fault schema and the injected crash is
    // visible in the counters: pods died and recovery replaced them.
    let text = serial.to_json().to_string_pretty();
    assert!(text.contains("\"schema_version\": 3"), "{text}");
    assert!(
        serial.rows.iter().any(|r| r.pods_evicted > 0),
        "the node crash must evict at least one pod somewhere in the grid"
    );
    assert!(
        serial.rows.iter().any(|r| r.pods_rescheduled > 0),
        "recovery must reschedule onto the surviving nodes"
    );
    for r in &serial.rows {
        // Recovery starts at most one replacement per lost pod; an attempt
        // that finds no feasible node counts unschedulable instead.
        assert!(
            r.pods_rescheduled <= r.pods_evicted,
            "rescheduled {} > evicted {} ({:?})",
            r.pods_rescheduled,
            r.pods_evicted,
            r.policy
        );
        assert!(r.completed > 0, "{:?}", r.policy);
    }
    // The swept failure probability is observable: the p=0 variant draws
    // nothing, so its rows record zero resize failures.
    let p0_failures: u64 = serial
        .rows
        .iter()
        .filter(|r| r.variant == "resize_failure_p=0")
        .map(|r| r.resize_failures)
        .sum();
    assert_eq!(p0_failures, 0, "p=0 variant must never fail a resize");
    assert!(
        serial.rows.iter().any(|r| r.variant == "resize_failure_p=0"),
        "expected the p=0 sweep variant in {:?}",
        serial.rows.iter().map(|r| r.variant.clone()).collect::<Vec<_>>()
    );
}

/// Re-running the same fault spec reproduces the same bytes — the seeded
/// fault RNG is part of the run's identity, not ambient randomness.
#[test]
fn fault_runs_are_reproducible_per_seed() {
    let a = ScenarioEngine::run(&crash_spec()).unwrap();
    let b = ScenarioEngine::run(&crash_spec()).unwrap();
    assert_eq!(
        a.to_json().to_string_pretty(),
        b.to_json().to_string_pretty()
    );
}

/// The exact v2 row key set, in the (alphabetical) order `BTreeMap` keys
/// iterate. A fault-free run must emit exactly these — nothing added,
/// nothing renamed — so pre-fault baselines diff clean.
const V2_ROW_KEYS: [&str; 19] = [
    "avg_committed_mcpu",
    "cold_starts",
    "completed",
    "failed",
    "inplace_scale_ups",
    "mean_ms",
    "mispredictions",
    "nodes",
    "p50_ms",
    "p99_ms",
    "pods_created",
    "policy",
    "rep",
    "routing",
    "scenario",
    "services",
    "speculative_resizes",
    "variant",
    "workload",
];

/// The no-faults byte-compatibility pin: a spec without a `faults` section
/// emits a v2 document whose rows carry exactly the pre-fault key set and
/// whose spec echo never mentions faults.
#[test]
fn fault_free_specs_keep_emitting_the_v2_document() {
    let spec = preset::by_name("smoke").unwrap();
    let report = ScenarioEngine::run(&spec).unwrap();
    let j = report.to_json();
    let text = j.to_string_pretty();
    assert!(text.contains("\"schema_version\": 2"), "{text}");
    for fault_key in [
        "faults",
        "pods_unschedulable",
        "pods_evicted",
        "pods_rescheduled",
        "resize_failures",
    ] {
        assert!(
            !text.contains(fault_key),
            "fault-free report leaked '{fault_key}':\n{text}"
        );
    }
    for row in j.req_arr("rows").unwrap() {
        let m = row.as_obj().unwrap();
        let keys: Vec<&str> = m.keys().map(String::as_str).collect();
        assert_eq!(keys, V2_ROW_KEYS, "v2 row key set drifted");
    }
}

/// The committed crash study runs end-to-end and analyzes: nonzero
/// eviction/reschedule counters flow from the simulated crash through the
/// report into the `kinetic analyze` aggregate table's fault columns.
#[test]
fn node_crash_example_shows_recovery_in_analyze() {
    let spec = ScenarioSpec::load(&scenarios_dir().join("node_crash.json")).unwrap();
    let report = ScenarioEngine::run_with_threads(&spec, 2).unwrap();
    let evicted: u64 = report.rows.iter().map(|r| r.pods_evicted).sum();
    let rescheduled: u64 = report.rows.iter().map(|r| r.pods_rescheduled).sum();
    assert!(evicted > 0, "the committed crash must evict pods");
    assert!(rescheduled > 0, "recovery must reschedule the evicted pods");

    let a = AnalysisReport::from_scenario(&report, Policy::Cold);
    let md = analysis::render(&a.aggregate_table(), analysis::Format::Markdown);
    assert!(
        md.contains("Evict") && md.contains("Resched"),
        "analyze must surface the recovery accounting:\n{md}"
    );
    // The run's counters survive the analysis round trip.
    let back = AnalysisReport::from_json(
        &kinetic::util::json::Json::parse(&a.to_json().to_string_pretty()).unwrap(),
    )
    .unwrap();
    assert_eq!(back, a);
}
