//! Forecast subsystem contract tests:
//!
//! 1. Predictor determinism: the histogram + window predictors are pure
//!    functions of the observation stream (property test under seeded
//!    replay).
//! 2. Graceful degradation: `predictive-inplace` is inert with no
//!    arrivals — no speculation, pod stays parked, never worse than
//!    `cold` on a zero-arrival trace.
//! 3. Speculation mechanics: a learned periodic gap pre-resizes the pod
//!    ahead of the next arrival (pre-empting the reactive hook), and a
//!    missed forecast re-parks the pod back to the parked allocation.
//! 4. Pool mechanics: `pooled` keeps its warm pool topped up when a
//!    request consumes a pod and trims the excess after the stable
//!    window.

use kinetic::coordinator::platform::Simulation;
use kinetic::forecast::{ArrivalPredictor, ForecastConfig};
use kinetic::policy::Policy;
use kinetic::simclock::SimTime;
use kinetic::trace::replay::{replay_with, ReplayConfig};
use kinetic::util::prop::{property, Gen};
use kinetic::util::quantity::MilliCpu;
use kinetic::workload::registry::{WorkloadKind, WorkloadProfile};

// ------------------------------------------------------------ determinism

/// Two predictors fed the identical randomized arrival stream must agree
/// on every intermediate forecast, rate sample and liveness answer — the
/// foundation of the byte-identical parallel reports.
#[test]
fn prop_predictors_deterministic_under_seed_replay() {
    property("predictors_deterministic", 200, |g: &mut Gen| {
        let cfg = ForecastConfig {
            bucket: SimTime::from_millis(g.u64(10, 5_000)),
            window: SimTime::from_secs(g.u64(1, 300)),
            horizon: SimTime::from_millis(g.u64(1, 10_000)),
            pool_size: 1,
        };
        let mut a = ArrivalPredictor::new(&cfg);
        let mut b = ArrivalPredictor::new(&cfg);
        let mut now = SimTime::ZERO;
        for _ in 0..g.usize(1, 60) {
            now = now + SimTime::from_millis_f64(g.f64(0.0, 30_000.0));
            a.observe(now);
            b.observe(now);
            if a.predict_gap() != b.predict_gap() {
                return Err(format!("predict_gap diverged at {now:?}"));
            }
            let probe = now + SimTime::from_millis(g.u64(0, 120_000));
            if a.rate_per_sec(probe) != b.rate_per_sec(probe) {
                return Err(format!("rate diverged at {probe:?}"));
            }
            if a.active_at(probe) != b.active_at(probe) {
                return Err(format!("active_at diverged at {probe:?}"));
            }
        }
        // Forecasts are also insensitive to *when* they are read: the
        // histogram side depends only on observations.
        if a.predict_gap() != b.predict_gap() {
            return Err("final forecast diverged".into());
        }
        Ok(())
    });
}

// --------------------------------------------------- graceful degradation

/// With zero arrivals the driver schedules nothing: the pod parks exactly
/// as under the §3 in-place policy, and `predictive-inplace` is no worse
/// than `cold` (both complete and fail nothing; predictive's only cost is
/// the 1 m parked reservation).
#[test]
fn predictive_inplace_is_inert_with_no_arrivals() {
    let mut sim = Simulation::paper(7);
    sim.deploy(
        "fn",
        WorkloadProfile::paper(WorkloadKind::HelloWorld),
        Policy::PredictiveInPlace,
    );
    sim.run();
    let deadline = sim.now() + SimTime::from_secs(600);
    sim.run_until(deadline);
    sim.run();

    let m = sim.world.metrics.service("fn");
    assert_eq!(m.speculative_resizes, 0, "no arrivals ⇒ no speculation");
    assert_eq!(m.mispredictions, 0);
    assert_eq!(m.completed, 0);
    assert_eq!(m.failed, 0);
    let pod = sim.world.services["fn"].pods[0].pod;
    let applied = sim.world.cluster.pod(pod).unwrap().status.applied_cpu_limit;
    assert_eq!(applied, MilliCpu(1), "pod must sit parked at 1 m");

    // The zero-arrival trace comparison vs cold: identical outcomes.
    for policy in [Policy::Cold, Policy::PredictiveInPlace] {
        let r = replay_with(&[], &ReplayConfig::paper(2, policy, 7));
        assert_eq!(r.completed, 0, "{policy:?}");
        assert_eq!(r.failed, 0, "{policy:?}");
        assert_eq!(r.mean_ms, 0.0, "{policy:?}");
    }
}

// ---------------------------------------------------- speculation cycle

/// Three arrivals 10 s apart teach the predictor the gap. The speculation
/// for arrival 3 pre-resizes the parked pod ahead of it (so the reactive
/// pre-hook finds the pod already at serving), and the speculation after
/// the *last* arrival goes unmet: the watchdog re-parks the pod and
/// counts one misprediction.
#[test]
fn speculation_preempts_the_hook_and_mispredictions_repark() {
    let mut sim = Simulation::paper(7);
    sim.deploy(
        "fn",
        WorkloadProfile::paper(WorkloadKind::HelloWorld),
        Policy::PredictiveInPlace,
    );
    sim.run(); // pod up + parked
    for s in [10u64, 20, 30] {
        let at = SimTime::from_secs(s);
        sim.submit_at(at, "fn");
    }
    sim.run(); // drains requests, the unmet speculation and the re-park

    let m = sim.world.metrics.service("fn");
    assert_eq!(m.completed, 3);
    assert_eq!(m.failed, 0);
    // Speculations fired for arrival 3 (a hit) and after arrival 3 (the
    // miss); the histogram needs two arrivals before the first forecast.
    assert!(
        m.speculative_resizes >= 2,
        "speculative_resizes = {}",
        m.speculative_resizes
    );
    assert_eq!(m.mispredictions, 1, "exactly the post-final-arrival miss");
    // The hit pre-empted the reactive pre-hook: only the first two
    // arrivals (pod still parked) paid a request-initiated scale-up.
    assert_eq!(
        m.inplace_scale_ups, 2,
        "arrival 3 must find the pod already at serving"
    );

    // After the re-park lands the pod is back at the parked allocation —
    // the misprediction restored the §3 idle state.
    let pod = sim.world.services["fn"].pods[0].pod;
    let applied = sim.world.cluster.pod(pod).unwrap().status.applied_cpu_limit;
    assert_eq!(applied, MilliCpu(1), "misprediction must re-park to 1 m");
}

/// The same service under plain in-place pays the reactive scale-up on
/// every arrival — the baseline the speculation removes.
#[test]
fn reactive_inplace_pays_the_hook_every_time() {
    let mut sim = Simulation::paper(7);
    sim.deploy(
        "fn",
        WorkloadProfile::paper(WorkloadKind::HelloWorld),
        Policy::InPlace,
    );
    sim.run();
    for s in [10u64, 20, 30] {
        sim.submit_at(SimTime::from_secs(s), "fn");
    }
    sim.run();
    let m = sim.world.metrics.service("fn");
    assert_eq!(m.completed, 3);
    assert_eq!(m.inplace_scale_ups, 3);
    assert_eq!(m.speculative_resizes, 0);
    assert_eq!(m.mispredictions, 0);
}

// ----------------------------------------------------------- warm pool

/// Pooled keeps `pool_size` idle warm pods: consuming one triggers a
/// refill, and the surplus trims back down after the stable window.
#[test]
fn pooled_refills_and_trims_the_warm_pool() {
    let mut sim = Simulation::paper(11);
    sim.deploy(
        "fn",
        WorkloadProfile::paper(WorkloadKind::HelloWorld),
        Policy::Pooled,
    );
    sim.run();
    let pool = sim.world.services["fn"].cfg.forecast.pool_size as usize;
    assert_eq!(
        sim.world.services["fn"].ready_pods(),
        pool,
        "deploy pre-creates the pool"
    );
    // Every pool pod sits at the full serving allocation (that is the
    // point of a warm pool: no resize, no startup on the request path).
    for sp in &sim.world.services["fn"].pods {
        let applied = sim
            .world
            .cluster
            .pod(sp.pod)
            .unwrap()
            .status
            .applied_cpu_limit;
        assert_eq!(applied, MilliCpu(1000));
    }

    sim.submit("fn");
    sim.run_to_quiescence();
    // The dispatch consumed a pool pod, so the driver started a refill;
    // once it is up the service briefly holds pool + 1 pods.
    let deadline = sim.now() + SimTime::from_secs(5);
    sim.run_until(deadline);
    assert_eq!(
        sim.world.services["fn"].ready_pods(),
        pool + 1,
        "refill must land after the startup pipeline"
    );
    assert_eq!(sim.world.metrics.service("fn").cold_starts, 0);

    // After the stable window the surplus pod retires back to the pool
    // target — and never below it.
    sim.run();
    assert_eq!(
        sim.world.services["fn"].ready_pods(),
        pool,
        "trim must stop at the pool target"
    );
    assert_eq!(sim.world.metrics.pods_deleted, 1);
}
