//! Golden regression pinning the `Topology::paper()` seeded end-to-end
//! metrics — the per-policy latency cells behind Table 3
//! (`experiments/policies.rs`) — to *exact* bit-level values, so hot-path
//! refactors (routing scores, incremental accounting, calibration
//! plumbing) cannot silently drift the paper reproduction.
//!
//! Workflow: the first run on a machine writes
//! `tests/golden/paper_policy_metrics.json` (bless-on-absence) and every
//! later run compares bit-for-bit. Commit the blessed file so CI pins the
//! values across refactors; after an *intentional* calibration change,
//! re-bless with `KINETIC_BLESS=1 cargo test --test golden_paper`.

use std::fs;
use std::path::{Path, PathBuf};

use kinetic::coordinator::accounting::RoutingPolicy;
use kinetic::experiments::policies::PolicyExperiment;
use kinetic::policy::Policy;
use kinetic::simclock::SimTime;
use kinetic::util::json::Json;
use kinetic::workload::registry::WorkloadKind;

/// Small/medium/large workloads cover the paper's latency regimes without
/// simulating the multi-minute video cells.
const KINDS: [WorkloadKind; 3] = [WorkloadKind::HelloWorld, WorkloadKind::Cpu, WorkloadKind::Io];

fn experiment(routing: RoutingPolicy) -> PolicyExperiment {
    PolicyExperiment {
        iterations: 4,
        think: SimTime::from_secs(8),
        seed: 9,
        routing,
    }
}

/// Every (workload, §3 policy) mean latency as exact f64 bits.
fn fingerprint(routing: RoutingPolicy) -> Vec<(String, u64)> {
    let exp = experiment(routing);
    let mut cells = Vec::new();
    for kind in KINDS {
        for policy in Policy::ALL {
            let ms = exp.measure_cell(kind, policy);
            cells.push((format!("{}/{}", kind.name(), policy.name()), ms.to_bits()));
        }
    }
    cells
}

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden/paper_policy_metrics.json")
}

fn write_golden(path: &Path, cells: &[(String, u64)]) {
    let obj = Json::obj(
        cells
            .iter()
            .map(|(k, bits)| (k.as_str(), Json::from(format!("0x{bits:016x}"))))
            .collect(),
    );
    let doc = Json::obj(vec![
        ("seed", 9u64.into()),
        ("iterations", 4u64.into()),
        ("routing", "least-loaded".into()),
        ("cells", obj),
    ]);
    fs::create_dir_all(path.parent().unwrap()).expect("create tests/golden");
    fs::write(path, doc.to_string_pretty()).expect("write golden file");
}

#[test]
fn golden_paper_policy_metrics_pinned() {
    let cells = fingerprint(RoutingPolicy::LeastLoaded);
    let path = golden_path();
    if std::env::var("KINETIC_BLESS").is_ok() {
        write_golden(&path, &cells);
        eprintln!(
            "golden_paper: blessed {} ({} cells) — commit it to pin the paper metrics",
            path.display(),
            cells.len()
        );
        return;
    }
    if !path.exists() {
        // Bless-on-absence keeps plain `cargo test` green on fresh
        // checkouts; the CI golden-gate step sets KINETIC_GOLDEN_REQUIRED
        // for its comparison run so an absent fixture can never make that
        // gate silently vacuous.
        assert!(
            std::env::var("KINETIC_GOLDEN_REQUIRED").is_err(),
            "golden file {} missing but required — bless it with \
             KINETIC_BLESS=1 cargo test --test golden_paper and commit it",
            path.display()
        );
        write_golden(&path, &cells);
        eprintln!(
            "golden_paper: blessed {} ({} cells) — commit it to pin the paper metrics",
            path.display(),
            cells.len()
        );
        return;
    }
    let txt = fs::read_to_string(&path).expect("read golden file");
    let doc = Json::parse(&txt).expect("golden file parses");
    assert_eq!(doc.req_u64("seed").unwrap(), 9, "golden seed changed");
    let golden = doc.get("cells").expect("golden has cells");
    for (name, bits) in &cells {
        let want_hex = golden
            .get(name)
            .and_then(|v| v.as_str())
            .unwrap_or_else(|| panic!("golden file missing cell {name}; re-bless with KINETIC_BLESS=1"));
        let want = u64::from_str_radix(want_hex.trim_start_matches("0x"), 16)
            .unwrap_or_else(|_| panic!("golden cell {name} is not hex bits: {want_hex}"));
        assert_eq!(
            *bits,
            want,
            "golden drift in {name}: got {} ms, golden {} ms — a hot-path change \
             altered the seeded paper reproduction; if intentional, re-bless with \
             KINETIC_BLESS=1 cargo test --test golden_paper",
            f64::from_bits(*bits),
            f64::from_bits(want)
        );
    }
}

/// The single-node, single-VU paper cells are routing-invariant: with one
/// candidate pod every scored policy must collapse to the same choice, so
/// the `--routing` knob can never perturb the paper reproduction.
#[test]
fn paper_metrics_identical_under_all_routing_policies() {
    let base = fingerprint(RoutingPolicy::LeastLoaded);
    for routing in [RoutingPolicy::Locality, RoutingPolicy::Hybrid] {
        let got = fingerprint(routing);
        assert_eq!(
            base, got,
            "{routing:?} drifted the Topology::paper() seeded metrics"
        );
    }
}
