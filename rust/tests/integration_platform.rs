//! Cross-module integration tests: the full platform driven end-to-end,
//! exercising cluster + apiserver + knative + policy + loadgen together —
//! the scenarios the paper's §4.2 narrative describes.

use kinetic::coordinator::platform::{Platform, Simulation};
use kinetic::coordinator::service::Service;
use kinetic::loadgen::arrival::Arrival;
use kinetic::loadgen::runner::{Runner, Scenario};
use kinetic::policy::{PlatformParams, Policy};
use kinetic::simclock::SimTime;
use kinetic::util::quantity::MilliCpu;
use kinetic::workload::registry::{WorkloadKind, WorkloadProfile};

fn sim(policy: Policy, kind: WorkloadKind, seed: u64) -> Simulation {
    let mut sim = Simulation::with_params(PlatformParams::with_seed(seed));
    sim.deploy("fn", WorkloadProfile::paper(kind), policy);
    sim.run();
    sim
}

/// `record_concurrency` now feeds the KPA autoscaler from the O(1)
/// per-service counters instead of rescanning every pod per tick. The
/// counter-based signal (`activator.len() + in_flight_pods`) must equal
/// the scan it replaced (`total_in_flight`) at *every* event boundary of a
/// bursty run — so the recorded autoscaler samples are unchanged.
#[test]
fn kpa_signal_matches_scan() {
    for policy in [Policy::Cold, Policy::Warm, Policy::InPlace] {
        let mut s = Simulation::with_params(PlatformParams::with_seed(29));
        s.deploy("fn", WorkloadProfile::paper(WorkloadKind::Cpu), policy);
        s.run();
        // Overlapping submissions drive queuing, activator buffering, KPA
        // scale-out and (in-place) resize churn.
        let mut at = s.now();
        for i in 0..12u64 {
            at = at + SimTime::from_millis(250 * (i % 4));
            s.submit_at(at, "fn");
        }
        let mut checked = 0u64;
        loop {
            let svc = &s.world.services["fn"];
            let fast = svc.activator.len() + svc.in_flight_pods as usize;
            assert_eq!(
                fast,
                svc.total_in_flight(),
                "{policy:?}: counter signal diverged from scan at {:?}",
                s.now()
            );
            checked += 1;
            if s.engine.step(&mut s.world).is_none() {
                break;
            }
        }
        assert!(checked > 50, "{policy:?}: only {checked} event boundaries");
        assert_eq!(s.world.metrics.service("fn").failed, 0);
    }
}

#[test]
fn paper_phase_diagram_cold_path() {
    // §3 Figure 1(A): request arrives after shutdown → full restart.
    let mut s = sim(Policy::Cold, WorkloadKind::HelloWorld, 1);
    let r = Runner::run(
        &mut s,
        "fn",
        &Scenario::closed_with_think(1, 3, SimTime::from_secs(10)),
    );
    assert_eq!(r.completed, 3);
    assert_eq!(r.cold_starts, 3, "every request must cold-start");
    assert!(r.mean_ms > 1000.0, "cold path must include the pipeline");
    // Pods were created and torn down repeatedly.
    assert_eq!(s.world.metrics.pods_created, 3);
}

#[test]
fn paper_phase_diagram_warm_path() {
    // §3 Figure 1(B): handler alive and idle → immediate dispatch.
    let mut s = sim(Policy::Warm, WorkloadKind::HelloWorld, 2);
    let r = Runner::run(
        &mut s,
        "fn",
        &Scenario::closed_with_think(1, 5, SimTime::from_secs(10)),
    );
    assert_eq!(r.completed, 5);
    assert_eq!(r.cold_starts, 0);
    assert!(r.mean_ms < 50.0, "warm ≈ runtime + proxy, got {}", r.mean_ms);
    assert_eq!(s.world.metrics.pods_created, 1, "single standing pod");
}

#[test]
fn paper_phase_diagram_inplace_path() {
    // §3 Figure 1(C): parked instance, scale up on arrival, down after.
    let mut s = sim(Policy::InPlace, WorkloadKind::HelloWorld, 3);
    let r = Runner::run(
        &mut s,
        "fn",
        &Scenario::closed_with_think(1, 5, SimTime::from_secs(10)),
    );
    assert_eq!(r.completed, 5);
    assert_eq!(r.cold_starts, 0);
    assert_eq!(r.inplace_scale_ups, 5, "each request triggers a scale-up");
    // Between cold and warm.
    assert!(r.mean_ms > 40.0 && r.mean_ms < 400.0, "got {}", r.mean_ms);
    // Scale-ups and parks both landed through the API server.
    assert!(s.world.metrics.resizes_accepted >= 10);
}

#[test]
fn inplace_back_to_back_requests_serialize_on_kubelet() {
    // Back-to-back requests churn up/down resizes; conflicts must be
    // retried, never lost, and all requests complete.
    let mut s = sim(Policy::InPlace, WorkloadKind::Cpu, 4);
    let r = Runner::run(&mut s, "fn", &Scenario::closed(1, 6));
    assert_eq!(r.completed, 6);
    assert!(
        s.world.metrics.resize_conflicts > 0,
        "down→up churn should hit the kubelet's per-pod serialization"
    );
}

#[test]
fn feature_gate_off_falls_back_to_no_resize() {
    // With the alpha gate disabled (k8s 1.27 default) the in-place hooks
    // can't do anything: patches are rejected, yet serving must still work
    // (the pod just stays at its boot-time serving allocation).
    let mut sim = Simulation::with_params(PlatformParams::with_seed(5));
    sim.world.api.gates.set(
        kinetic::apiserver::gates::IN_PLACE_POD_VERTICAL_SCALING,
        false,
    );
    sim.deploy(
        "fn",
        WorkloadProfile::paper(WorkloadKind::HelloWorld),
        Policy::InPlace,
    );
    sim.run();
    let r = Runner::run(&mut sim, "fn", &Scenario::closed(1, 4));
    assert_eq!(r.completed, 4);
    assert_eq!(sim.world.metrics.resizes_accepted, 0);
    // Pod never parked: still at serving CPU.
    let pod = sim.world.services["fn"].pods[0].pod;
    assert_eq!(
        sim.world.cluster.pod(pod).unwrap().status.applied_cpu_limit,
        MilliCpu(1000)
    );
}

#[test]
fn open_loop_burst_queues_and_completes() {
    let mut s = sim(Policy::InPlace, WorkloadKind::Io, 6);
    let r = Runner::run(
        &mut s,
        "fn",
        &Scenario::Open {
            arrival: Arrival::Bursty {
                period: SimTime::from_secs(20),
                burst_n: 6,
            },
            horizon: SimTime::from_secs(60),
        },
    );
    assert_eq!(r.failed, 0);
    assert_eq!(r.completed, 18);
    // Burst members share the pod → p99 well above p50.
    assert!(r.p99_ms > r.p50_ms);
}

#[test]
fn multi_service_isolation() {
    // Two services on one node: metrics and pods must not bleed.
    let mut sim = Simulation::with_params(PlatformParams::with_seed(7));
    sim.deploy(
        "a",
        WorkloadProfile::paper(WorkloadKind::HelloWorld),
        Policy::Warm,
    );
    sim.deploy(
        "b",
        WorkloadProfile::paper(WorkloadKind::Io),
        Policy::InPlace,
    );
    sim.run();
    for _ in 0..4 {
        sim.submit("a");
        sim.submit("b");
    }
    sim.run();
    let ma = sim.world.metrics.service("a");
    assert_eq!(ma.completed, 4);
    assert_eq!(ma.inplace_scale_ups, 0);
    let mb = sim.world.metrics.service("b");
    assert_eq!(mb.completed, 4);
    assert!(mb.inplace_scale_ups >= 1);
}

#[test]
fn node_capacity_respected_under_many_services() {
    // 8-core node; warm services reserve 1 CPU each. The 9th+ pod must not
    // fit — deploys succeed but pods beyond capacity stay unscheduled.
    let mut sim = Simulation::with_params(PlatformParams::with_seed(8));
    for i in 0..10 {
        sim.deploy(
            &format!("svc-{i}"),
            WorkloadProfile::paper(WorkloadKind::HelloWorld),
            Policy::Warm,
        );
    }
    sim.run();
    let ready: usize = sim
        .world
        .services
        .values()
        .map(|s| s.ready_pods())
        .sum();
    assert!(ready <= 8, "ready={ready} cannot exceed node cores");
    let reserved = sim.world.cluster.total_reserved();
    assert!(reserved.cpu <= MilliCpu(8000));
}

#[test]
fn concurrency_limit_queues_at_proxy() {
    let mut sim = Simulation::with_params(PlatformParams::with_seed(9));
    let mut cfg = Policy::Warm.revision_config();
    cfg.container_concurrency = 1;
    cfg.max_scale = 1;
    let svc = Service::with_config(
        "fn",
        WorkloadProfile::paper(WorkloadKind::Cpu),
        Policy::Warm,
        cfg,
    );
    sim.deploy_service(svc);
    sim.run();
    // Two simultaneous requests; CC=1 → strictly serial execution.
    sim.submit("fn");
    sim.submit("fn");
    sim.run();
    let mut lat = sim.world.metrics.service("fn").latency_ms.clone();
    assert_eq!(lat.len(), 2);
    // Second request waits for the first: ~2× runtime, not CPU-shared.
    let max = lat.max();
    assert!(max > 4500.0, "serialized second request, got {max}");
    let min = lat.min();
    assert!(min < 3000.0, "first request unqueued, got {min}");
}

#[test]
fn deterministic_end_to_end() {
    let run = || {
        let mut s = sim(Policy::InPlace, WorkloadKind::Cpu, 1234);
        let r = Runner::run(&mut s, "fn", &Scenario::closed(3, 4));
        (r.completed, r.mean_ms.to_bits(), r.p99_ms.to_bits())
    };
    assert_eq!(run(), run());
}

#[test]
fn committed_cpu_tracks_policy_difference_over_a_day() {
    // One hour of sparse traffic: the §3 "enhanced resource availability"
    // claim quantified.
    let measure = |policy: Policy| -> f64 {
        let mut s = sim(policy, WorkloadKind::HelloWorld, 11);
        let start = s.now();
        for i in 0..30u64 {
            s.submit_at(start + SimTime::from_secs(i * 120), "fn");
        }
        s.run();
        let end = s.now().max(start + SimTime::from_secs(3600));
        s.run_until(end);
        s.world.metrics.committed_cpu.average_mcpu(end)
    };
    let warm = measure(Policy::Warm);
    let inplace = measure(Policy::InPlace);
    let cold = measure(Policy::Cold);
    assert!(warm > 900.0, "warm federates a full CPU: {warm}");
    assert!(inplace < 60.0, "in-place parks at ~1m: {inplace}");
    assert!(cold < inplace + 50.0, "cold commits nothing while idle: {cold}");
}

/// The platform is the public API — keep the documented entry points
/// compiling exactly as README shows them.
#[test]
fn readme_snippet_compiles_and_runs() {
    let mut sim = Simulation::paper(42);
    sim.deploy(
        "hello",
        WorkloadProfile::paper(WorkloadKind::HelloWorld),
        Policy::InPlace,
    );
    sim.run();
    sim.submit("hello");
    sim.run();
    let m = sim.world.metrics.service("hello");
    assert_eq!(m.completed, 1);
    let _: &Platform = &sim.world;
}
