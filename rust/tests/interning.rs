//! End-to-end locks for the `ServiceId` interning overhaul: scenario
//! reports must not change by a byte now that the hot path carries dense
//! interned ids instead of `String`/`Arc<str>` service keys.
//!
//! Four contracts:
//!
//! 1. **Byte identity across execution shapes** — the committed smoke,
//!    predictive and node-crash studies emit identical bytes at
//!    `--threads {1,4}`, and (separately) identical bytes at
//!    `--shards {1,4}` at either thread count. Interning is per-cell in
//!    the sharded runtime, so this also pins the name-addressed wire
//!    format at window barriers.
//! 2. **Pinned expectations** — the serial report for each study is
//!    blessed into `tests/golden/` on first run (the `golden_paper.rs`
//!    workflow: commit the fixture; CI sets `KINETIC_GOLDEN_REQUIRED` so
//!    an absent file can never make the gate vacuous) and compared
//!    byte-for-byte ever after.
//! 3. **Intern-table determinism** — ids are assigned in first-seen
//!    deploy order, identically across runs, with the lexicographic
//!    sweep order preserved through the side index.
//! 4. **No strings on the hot path** — a source-level grep gate over the
//!    dispatch/complete/resize/forecast modules.

use std::fs;
use std::path::PathBuf;
use std::sync::Once;

use kinetic::coordinator::platform::Simulation;
use kinetic::policy::Policy;
use kinetic::scenario::{ScenarioEngine, ScenarioReport, ScenarioSpec};
use kinetic::util::intern::{Interner, ServiceId};
use kinetic::workload::registry::{WorkloadKind, WorkloadProfile};

/// The predictive study's trace path is CWD-relative from the repo root
/// (the CLI contract); every other path in this binary is manifest-
/// absolute, so pinning the whole test binary's CWD to the repo root is
/// safe and makes all three specs loadable the same way.
fn repo_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("..")
}

fn pin_cwd() {
    static ONCE: Once = Once::new();
    ONCE.call_once(|| {
        std::env::set_current_dir(repo_root()).expect("chdir to repo root");
    });
}

fn load_spec(file: &str) -> ScenarioSpec {
    pin_cwd();
    let path = repo_root().join("examples/scenarios").join(file);
    ScenarioEngine::load(path.to_str().unwrap()).unwrap_or_else(|e| panic!("{file}: {e}"))
}

fn render(r: &ScenarioReport) -> String {
    r.to_json().to_string_pretty()
}

const STUDIES: [&str; 3] = ["smoke.json", "predictive_azure.json", "node_crash.json"];

// ---------------------------------------------------------- byte identity

/// Classic (single-coordinator) runs: the worker count must not change a
/// byte, and re-running the same spec reproduces the same bytes — which
/// also pins that no `HashMap`/`HashSet` iteration order leaks into a
/// report (the surviving hash containers are lookup-only).
#[test]
fn classic_reports_byte_identical_across_thread_counts() {
    for file in STUDIES {
        let spec = load_spec(file);
        let serial = render(&ScenarioEngine::run_with_threads(&spec, 1).unwrap());
        let parallel = render(&ScenarioEngine::run_with_threads(&spec, 4).unwrap());
        assert_eq!(serial, parallel, "{file}: report depends on --threads");
        let again = render(&ScenarioEngine::run_with_threads(&spec, 1).unwrap());
        assert_eq!(serial, again, "{file}: report not reproducible per seed");
    }
}

/// Sharded runs: interned ids live per cell and service names cross the
/// shard boundary as the wire format, so the report must be identical at
/// any shard count — at either thread count.
#[test]
fn sharded_reports_byte_identical_across_shard_and_thread_counts() {
    for file in STUDIES {
        let spec = load_spec(file);
        let base = render(&ScenarioEngine::run_with_options(&spec, 1, Some(1)).unwrap());
        for (threads, shards) in [(4, 1), (1, 4), (4, 4)] {
            let got =
                render(&ScenarioEngine::run_with_options(&spec, threads, Some(shards)).unwrap());
            assert_eq!(
                base, got,
                "{file}: sharded report diverged at --threads {threads} --shards {shards}"
            );
        }
    }
}

// ------------------------------------------------------- pinned fixtures

fn golden_path(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/golden")
        .join(format!("report_{name}.json"))
}

/// The serial classic report for each study, pinned byte-for-byte against
/// a committed fixture (bless-on-absence; `KINETIC_BLESS=1` re-blesses
/// after an intentional behavior change).
#[test]
fn study_reports_match_committed_expectations() {
    for file in STUDIES {
        let spec = load_spec(file);
        let report = ScenarioEngine::run(&spec).unwrap();
        let text = render(&report);
        let path = golden_path(&spec.name);
        let blessing = std::env::var("KINETIC_BLESS").is_ok();
        if blessing || !path.exists() {
            assert!(
                blessing || std::env::var("KINETIC_GOLDEN_REQUIRED").is_err(),
                "fixture {} missing but required — bless it with \
                 KINETIC_BLESS=1 cargo test --test interning and commit it",
                path.display()
            );
            fs::create_dir_all(path.parent().unwrap()).expect("create tests/golden");
            fs::write(&path, &text).expect("write report fixture");
            eprintln!(
                "interning: blessed {} — commit it to pin the {} report",
                path.display(),
                spec.name
            );
            continue;
        }
        let want = fs::read_to_string(&path).expect("read report fixture");
        assert_eq!(
            text,
            want,
            "{file}: report drifted from the committed expectation {} — the \
             state-layer overhaul must not change report bytes; if the change \
             is intentional, re-bless with KINETIC_BLESS=1 cargo test --test interning",
            path.display()
        );
    }
}

// ------------------------------------------------- intern determinism

/// Ids are dense, assigned in first-seen order, stable across identical
/// runs, and the name-ordered sweep the RNG-bearing loops walk matches
/// the old `BTreeMap<String, _>` iteration exactly.
#[test]
fn intern_table_assignment_is_deterministic() {
    // Deploy order deliberately differs from name order (fn-10 < fn-2
    // lexicographically).
    let names = ["fn-2", "fn-0", "fn-10", "fn-1"];
    let build = || {
        let mut sim = Simulation::paper(3);
        for n in &names {
            sim.deploy(n, WorkloadProfile::paper(WorkloadKind::HelloWorld), Policy::Cold);
        }
        sim
    };
    let a = build();
    let b = build();
    for (i, n) in names.iter().enumerate() {
        let id = a.world.services.id_of(n).unwrap();
        assert_eq!(id, ServiceId(i as u32), "{n}: ids follow deploy order");
        assert_eq!(id, b.world.services.id_of(n).unwrap(), "{n}: ids differ across runs");
        assert_eq!(&**a.world.services.name(id), *n);
    }
    let by_name: Vec<ServiceId> = a.world.services.ids_by_name().collect();
    assert_eq!(
        by_name,
        vec![ServiceId(1), ServiceId(3), ServiceId(2), ServiceId(0)],
        "sweep order is lexicographic, not deploy order"
    );

    // The raw interner is idempotent and first-seen ordered.
    let mut t = Interner::default();
    assert_eq!(t.intern("b"), ServiceId(0));
    assert_eq!(t.intern("a"), ServiceId(1));
    assert_eq!(t.intern("b"), ServiceId(0), "re-intern returns the same id");
    let order: Vec<ServiceId> = t.ids_by_name().collect();
    assert_eq!(order, vec![ServiceId(1), ServiceId(0)]);
}

// ------------------------------------------------------------- grep gate

/// No `String`/`Arc<str>` service keys on the dispatch/complete/resize/
/// forecast hot path: events and handlers carry `ServiceId`; name-keyed
/// lookups (`Metrics::service`, `Services::get_by_name`, string indexing)
/// are boundary-only.
#[test]
fn hot_path_carries_service_ids_not_strings() {
    let src = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("src");
    let gated = [
        "coordinator/routing.rs",
        "coordinator/lifecycle.rs",
        "coordinator/resize.rs",
        "coordinator/event.rs",
        "forecast/driver.rs",
    ];
    let forbidden = [
        "metrics.service(",
        "service: &str",
        "service: String",
        "service: Arc<str>",
        "svc: &str",
        "services.get_by_name",
        "services[\"",
    ];
    for file in gated {
        let text = fs::read_to_string(src.join(file)).unwrap_or_else(|e| panic!("{file}: {e}"));
        // Strip the in-module test block: tests exercise the name-keyed
        // boundary surface on purpose.
        let hot = match text.find("#[cfg(test)]") {
            Some(i) => &text[..i],
            None => &text[..],
        };
        assert!(
            hot.contains("ServiceId"),
            "{file}: expected interned ServiceId on the hot path"
        );
        for pat in forbidden {
            assert!(
                !hot.contains(pat),
                "{file}: string service key `{pat}` crept back onto the hot path"
            );
        }
    }
}
