//! Integration locks for the observation plane (`obs/`):
//!
//! 1. The hard invariant — arming observation changes NOTHING about the
//!    scenario report: byte-identical to the plain run at every
//!    `--threads` × `--shards` combination.
//! 2. Sharded observation: the span-plane artifacts (summary, Chrome
//!    trace, spans JSONL) are identical at any shard count thanks to
//!    window-relative timestamps and canonical merge order. The timeline
//!    plane is pinned deterministic for a *fixed* shard count (its
//!    per-node vectors are partitioned per cell, so cross-count identity
//!    is structurally impossible — see `write_obs_artifacts` in main.rs).
//! 3. Export schema round-trips through the strict validators; unknown
//!    keys and wrong kinds are rejected with their path.
//! 4. Physics: the telescoped phase marks of a span never exceed the
//!    end-to-end latency the report records for it.
//! 5. `sample_1_in_n` is deterministic per seed: reruns pick the same
//!    spans, and every kept index within a service shares one residue.

use kinetic::coordinator::event::Event;
use kinetic::obs::export;
use kinetic::obs::{ObserveConfig, SpanOutcome};
use kinetic::scenario::{ScenarioEngine, ScenarioReport, ScenarioSpec};
use kinetic::util::json::Json;

fn spec() -> ScenarioSpec {
    ScenarioSpec::parse(
        r#"{
        "name": "obs-lock",
        "workload": {"type": "synthetic", "services": 4,
                     "rate_per_service": 0.3, "horizon_s": 60},
        "topology": {"kind": "uniform", "nodes": 4},
        "policies": ["warm", "in-place"]
    }"#,
    )
    .unwrap()
}

fn bytes(r: &ScenarioReport) -> Vec<u8> {
    r.to_json().to_string_pretty().into_bytes()
}

/// The invariant the whole subsystem hangs off: observation is read-only.
/// For every threads × shards combination, the observed report is
/// byte-for-byte the plain report.
#[test]
fn observed_report_is_byte_identical_to_plain() {
    let spec = spec();
    let cfg = ObserveConfig::default();
    for threads in [1usize, 4] {
        for shards in [None, Some(1u32), Some(4)] {
            let plain = ScenarioEngine::run_with_options(&spec, threads, shards).unwrap();
            let (observed, obs) =
                ScenarioEngine::run_observed(&spec, threads, shards, Some(&cfg)).unwrap();
            assert_eq!(
                bytes(&plain),
                bytes(&observed),
                "report diverged under observation at threads={threads} shards={shards:?}"
            );
            assert_eq!(
                obs.len(),
                observed.rows.len(),
                "one bundle per run at threads={threads} shards={shards:?}"
            );
            assert!(
                obs.iter().all(|r| !r.bundle.spans.is_empty()),
                "every run must close spans at threads={threads} shards={shards:?}"
            );
        }
    }
}

/// Span-plane artifacts are identical at any shard count: per-cell trace
/// buffers merge in canonical (service, index) order and every timestamp
/// is window-relative, so per-cell settle jitter cancels out.
#[test]
fn span_artifacts_are_identical_across_shard_counts() {
    let spec = spec();
    let cfg = ObserveConfig {
        timeline: false,
        ..ObserveConfig::default()
    };
    let (_, one) = ScenarioEngine::run_observed(&spec, 1, Some(1), Some(&cfg)).unwrap();
    for n in [2u32, 4] {
        let (_, many) = ScenarioEngine::run_observed(&spec, 1, Some(n), Some(&cfg)).unwrap();
        assert_eq!(
            export::summary_doc("obs-lock", &one, &[0; 4]).to_string_pretty(),
            export::summary_doc("obs-lock", &many, &[0; 4]).to_string_pretty(),
            "summary diverged at --shards {n}"
        );
        assert_eq!(
            export::trace_doc(&one).to_string_pretty(),
            export::trace_doc(&many).to_string_pretty(),
            "Chrome trace diverged at --shards {n}"
        );
        assert_eq!(
            export::spans_jsonl(&one),
            export::spans_jsonl(&many),
            "spans JSONL diverged at --shards {n}"
        );
    }
}

/// The timeline plane is deterministic for a fixed shard count: two runs
/// of the same spec at the same count produce identical gauges.
#[test]
fn timeline_is_deterministic_for_a_fixed_shard_count() {
    let spec = spec();
    let cfg = ObserveConfig::default();
    for shards in [None, Some(2u32)] {
        let (_, a) = ScenarioEngine::run_observed(&spec, 1, shards, Some(&cfg)).unwrap();
        let (_, b) = ScenarioEngine::run_observed(&spec, 1, shards, Some(&cfg)).unwrap();
        assert!(
            a.iter().any(|r| !r.bundle.timeline.is_empty()),
            "cadence sampler must record gauges at shards={shards:?}"
        );
        assert_eq!(
            export::timeline_doc("obs-lock", &a).to_string_pretty(),
            export::timeline_doc("obs-lock", &b).to_string_pretty(),
            "timeline JSON not deterministic at shards={shards:?}"
        );
        assert_eq!(
            export::timeline_csv(&a),
            export::timeline_csv(&b),
            "timeline CSV not deterministic at shards={shards:?}"
        );
    }
}

/// Every export surface round-trips its own strict validator, and the
/// validators reject unknown keys and foreign kinds with their path.
#[test]
fn exports_validate_and_reject_unknown_keys() {
    let spec = spec();
    let cfg = ObserveConfig::default();
    let (_, obs) = ScenarioEngine::run_observed(&spec, 1, None, Some(&cfg)).unwrap();

    let summary = export::summary_doc("obs-lock", &obs, &[0, 1, 2, 3]);
    export::validate_summary(&summary).expect("summary must self-validate");
    let trace = export::trace_doc(&obs);
    export::validate_trace(&trace).expect("trace must self-validate");
    let timeline = export::timeline_doc("obs-lock", &obs);
    export::validate_timeline(&timeline).expect("timeline must self-validate");
    let profile = export::profile_doc(&obs[0].bundle.profile, &Event::KINDS);
    export::validate_profile(&profile).expect("profile must self-validate");

    // Unknown top-level key: strict parse refuses it by name.
    let mut doctored = summary.clone();
    if let Json::Obj(map) = &mut doctored {
        map.insert("surprise".into(), Json::Bool(true));
    }
    let e = export::validate_summary(&doctored).unwrap_err();
    assert!(e.contains("surprise"), "must name the unknown key: {e}");

    // Foreign kind: a timeline document is not a summary document.
    let e = export::validate_summary(&timeline).unwrap_err();
    assert!(e.contains("kind"), "must flag the kind mismatch: {e}");

    // Unknown key nested inside a run entry is rejected too.
    let mut doctored = summary;
    if let Json::Obj(map) = &mut doctored {
        if let Some(Json::Arr(runs)) = map.get_mut("runs") {
            if let Some(Json::Obj(run)) = runs.first_mut() {
                run.insert("extra".into(), Json::Num(1.0));
            }
        }
    }
    let e = export::validate_summary(&doctored).unwrap_err();
    assert!(e.contains("extra"), "must name the nested unknown key: {e}");
}

/// A span's marks telescope: the interval from first to last mark can
/// never exceed the end-to-end latency the report records (the report's
/// latency additionally includes the proxy respond hop).
#[test]
fn phase_marks_telescope_within_latency() {
    let spec = spec();
    let cfg = ObserveConfig::default();
    let (_, obs) = ScenarioEngine::run_observed(&spec, 1, None, Some(&cfg)).unwrap();
    let mut completed = 0u64;
    for run in &obs {
        for span in &run.bundle.spans {
            assert!(
                span.marks.windows(2).all(|w| w[0].1 <= w[1].1),
                "marks must be time-ordered: {}#{}",
                span.service,
                span.index
            );
            if let Some(latency) = span.latency_ms {
                assert_eq!(span.outcome, SpanOutcome::Completed);
                assert!(
                    span.marked_ms() <= latency + 1e-6,
                    "{}#{}: marked {} ms exceeds end-to-end {} ms",
                    span.service,
                    span.index,
                    span.marked_ms(),
                    latency
                );
                completed += 1;
            }
        }
    }
    assert!(completed > 0, "the run must complete observed requests");
}

/// `sample_1_in_n` rides the seeded RNG discipline: reruns are identical,
/// and the kept indices of each service share a single residue mod n —
/// the per-service offset drawn from the observation seed.
#[test]
fn sampling_is_deterministic_per_seed() {
    let spec = spec();
    let cfg = ObserveConfig {
        sample_1_in_n: 4,
        ..ObserveConfig::default()
    };
    let (_, a) = ScenarioEngine::run_observed(&spec, 1, None, Some(&cfg)).unwrap();
    let (_, b) = ScenarioEngine::run_observed(&spec, 1, None, Some(&cfg)).unwrap();
    assert_eq!(
        export::spans_jsonl(&a),
        export::spans_jsonl(&b),
        "sampling must be identical across reruns of the same spec"
    );
    let mut sampled = 0u64;
    for run in &a {
        let mut offsets: std::collections::BTreeMap<&str, u64> = Default::default();
        for span in &run.bundle.spans {
            let residue = span.index % cfg.sample_1_in_n;
            let prev = offsets.entry(span.service.as_str()).or_insert(residue);
            assert_eq!(
                *prev, residue,
                "service {} mixes residues {} and {} at 1-in-{}",
                span.service, prev, residue, cfg.sample_1_in_n
            );
            sampled += 1;
        }
    }
    assert!(sampled > 0, "1-in-4 sampling must still keep some spans");
}
