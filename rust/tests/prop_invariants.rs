//! Property-based invariants over the coordinator substrates (DESIGN.md
//! §6c): CFS work conservation, resize state-machine safety, routing/request
//! conservation through the platform, autoscaler window math, and the
//! latency model's monotonicity guarantees.

use kinetic::cgroup::cfs::{CfsArbiter, CfsShare};
use kinetic::cgroup::latency::{LatencyModel, NodeLoad};
use kinetic::cluster::topology::{NodeShape, Topology};
use kinetic::coordinator::accounting::RoutingPolicy;
use kinetic::coordinator::platform::Simulation;
use kinetic::knative::autoscaler::Autoscaler;
use kinetic::knative::config::RevisionConfig;
use kinetic::policy::Policy;
use kinetic::simclock::SimTime;
use kinetic::util::prop::{property, Gen};
use kinetic::util::quantity::{Memory, MilliCpu, Resources};
use kinetic::workload::exec::Execution;
use kinetic::workload::registry::{WorkloadKind, WorkloadProfile};

/// CFS: rates never exceed caps/demands, never exceed capacity, and the
/// arbiter is work-conserving under saturation.
#[test]
fn prop_cfs_work_conservation() {
    property("cfs_work_conservation", 300, |g: &mut Gen| {
        let capacity = MilliCpu(g.u64(100, 16_000));
        let n = g.usize(1, 12);
        let entities: Vec<CfsShare> = (0..n)
            .map(|_| {
                let weight = g.u64(1, 10_000);
                let limit = if g.bool() {
                    Some(MilliCpu(g.millicpu()))
                } else {
                    None
                };
                let demand = MilliCpu(g.u64(0, 12_000));
                CfsShare::new(weight, limit, demand)
            })
            .collect();
        let arb = CfsArbiter::new(capacity);
        let rates = arb.allocate(&entities);

        let mut total = 0u64;
        for (e, r) in entities.iter().zip(&rates) {
            if let Some(l) = e.limit {
                if *r > l {
                    return Err(format!("rate {r} exceeds limit {l}"));
                }
            }
            if r.0 > e.demand.0 + 1 {
                return Err(format!("rate {r} exceeds demand {}", e.demand));
            }
            total += r.0;
        }
        if total > capacity.0 + entities.len() as u64 {
            return Err(format!("total {total} exceeds capacity {capacity}"));
        }
        // Work conservation: when aggregate eligible demand saturates the
        // node, the node is fully used (up to rounding).
        let eligible: u64 = entities
            .iter()
            .map(|e| e.limit.map(|l| l.0).unwrap_or(u64::MAX / 2).min(e.demand.0))
            .sum();
        if eligible >= capacity.0 && total + entities.len() as u64 * 2 < capacity.0 {
            return Err(format!(
                "not work conserving: total {total} < capacity {capacity} with eligible {eligible}"
            ));
        }
        Ok(())
    });
}

/// Resize latency model: positive, finite, and monotone in the target for
/// scale-down (Fig 4b's shape) for any load.
#[test]
fn prop_latency_model_sane() {
    property("latency_model_sane", 200, |g: &mut Gen| {
        let model = LatencyModel::default();
        let load = NodeLoad {
            cpu_utilization: g.f64(0.0, 1.0),
            io_stress: g.bool(),
        };
        let cur = g.millicpu();
        let tgt = g.millicpu();
        let ms = model.mean_ms(cur, tgt, load);
        if !(ms.is_finite() && ms > 0.0) {
            return Err(format!("mean_ms({cur},{tgt}) = {ms}"));
        }
        if ms > 60_000.0 {
            return Err(format!("implausible latency {ms} ms"));
        }
        // Scale-down monotonicity in target.
        let t1 = g.u64(1, 500);
        let t2 = t1 + g.u64(1, 499);
        let down_small = model.mean_ms(1000, t1, load);
        let down_large = model.mean_ms(1000, t2, load);
        if down_small + 1e-9 < down_large {
            return Err(format!(
                "down-latency not monotone: target {t1} => {down_small}, target {t2} => {down_large}"
            ));
        }
        Ok(())
    });
}

/// Execution progress: piecewise integration over arbitrary allocation
/// schedules conserves work — total progress equals the sum of segment
/// contributions, and completion time at constant allocation matches the
/// closed form.
#[test]
fn prop_execution_work_conservation() {
    property("execution_work_conservation", 200, |g: &mut Gen| {
        let kinds = [
            WorkloadKind::HelloWorld,
            WorkloadKind::Cpu,
            WorkloadKind::Io,
            WorkloadKind::Video10s,
        ];
        let profile = WorkloadProfile::paper(*g.choose(&kinds));
        let mut exec = Execution::start(&profile, SimTime::ZERO);
        let mut now = SimTime::ZERO;
        let segments = g.usize(1, 10);
        let mut spent = 0.0f64;
        for _ in 0..segments {
            let alloc = MilliCpu(g.millicpu());
            let dt = SimTime::from_millis_f64(g.f64(0.1, 500.0));
            let before = exec.remaining_default_ms();
            exec.advance(now + dt, alloc);
            let after = exec.remaining_default_ms();
            if after > before + 1e-9 {
                return Err("remaining work increased".to_string());
            }
            spent += before - after;
            now = now + dt;
            if exec.done() {
                break;
            }
        }
        let accounted = profile.runtime_1cpu_ms - exec.remaining_default_ms();
        if (accounted - spent).abs() > 1e-6 {
            return Err(format!("work leak: accounted {accounted} vs spent {spent}"));
        }
        Ok(())
    });
}

/// Routing conservation: every submitted request is eventually exactly one
/// of {completed, failed}; none vanish, none double-count — across random
/// policies, workloads and burst patterns.
#[test]
fn prop_request_conservation() {
    property("request_conservation", 25, |g: &mut Gen| {
        let policy = *g.choose(&[Policy::Cold, Policy::Warm, Policy::InPlace]);
        let kind = *g.choose(&[
            WorkloadKind::HelloWorld,
            WorkloadKind::Cpu,
            WorkloadKind::Io,
        ]);
        let mut sim = Simulation::paper(g.u64(0, u64::MAX / 2));
        sim.deploy("fn", WorkloadProfile::paper(kind), policy);
        sim.run();

        let n = g.usize(1, 24) as u64;
        let mut at = sim.now();
        for _ in 0..n {
            at = at + SimTime::from_millis_f64(g.f64(0.0, 9000.0));
            sim.submit_at(at, "fn");
        }
        sim.run();

        let in_flight = sim.world.in_flight();
        let m = sim.world.metrics.service("fn");
        let total = m.completed + m.failed;
        if total != n {
            return Err(format!(
                "submitted {n}, accounted {total} (completed {} failed {})",
                m.completed, m.failed
            ));
        }
        if in_flight != 0 {
            return Err(format!("{in_flight} requests still in flight"));
        }
        // Latency samples match completions.
        if m.latency_ms.len() as u64 != m.completed {
            return Err("latency sample count != completions".to_string());
        }
        Ok(())
    });
}

/// Autoscaler: the window average is always within [0, max concurrency
/// recorded], and decisions respect min/max bounds.
#[test]
fn prop_autoscaler_bounds() {
    property("autoscaler_bounds", 200, |g: &mut Gen| {
        let min = g.u64(0, 3) as u32;
        let max = min + g.u64(1, 8) as u32;
        let cfg = RevisionConfig {
            min_scale: min,
            max_scale: max,
            stable_window: SimTime::from_secs(g.u64(2, 60)),
            target_concurrency: g.f64(0.5, 20.0),
            ..RevisionConfig::default()
        };
        let mut a = Autoscaler::new(cfg.clone());
        let mut now = SimTime::ZERO;
        let mut max_seen = 0u32;
        for _ in 0..g.usize(1, 40) {
            now = now + SimTime::from_millis_f64(g.f64(1.0, 5000.0));
            let c = g.u64(0, 40) as u32;
            max_seen = max_seen.max(c);
            a.record(now, c);
        }
        let avg = a.window_average(now, cfg.stable_window);
        if !(0.0..=max_seen as f64 + 1e-9).contains(&avg) {
            return Err(format!("window avg {avg} outside [0, {max_seen}]"));
        }
        let d = a.decide(now, g.u64(0, 8) as u32);
        if d.desired < min || d.desired > max {
            return Err(format!("desired {} outside [{min}, {max}]", d.desired));
        }
        Ok(())
    });
}

/// Multi-node capacity safety: across random topologies (uniform and
/// heterogeneous), random policy/workload mixes and bursty traffic —
/// which drives scheduling, KPA scale-out, scale-to-zero teardown and
/// in-place resizes — no node's reserved requests ever exceed its
/// capacity, and no pod's applied CPU limit ever exceeds the capacity of
/// the node it runs on. Checked mid-flight and after quiescence.
#[test]
fn prop_fleet_never_overcommits_nodes() {
    property("fleet_never_overcommits_nodes", 12, |g: &mut Gen| {
        // Random fleet: 1–6 nodes, 2–16 cores and 4–16 GiB each.
        let n_nodes = g.usize(1, 6);
        let shapes: Vec<NodeShape> = (0..n_nodes)
            .map(|i| {
                NodeShape::new(
                    &format!("node-{i}"),
                    Resources::new(
                        MilliCpu(g.u64(2, 16) * 1000),
                        Memory::from_gib(g.u64(4, 16)),
                    ),
                )
            })
            .collect();
        let topology = Topology::heterogeneous(shapes);
        let mut sim = Simulation::fleet(topology, g.u64(0, u64::MAX / 2));

        let n_services = g.usize(1, 8);
        for i in 0..n_services {
            let policy = *g.choose(&[Policy::Cold, Policy::Warm, Policy::InPlace]);
            let kind = *g.choose(&[
                WorkloadKind::HelloWorld,
                WorkloadKind::Cpu,
                WorkloadKind::Io,
            ]);
            sim.deploy(&format!("fn-{i}"), WorkloadProfile::paper(kind), policy);
        }
        sim.run();

        let check = |sim: &Simulation, when: &str| -> Result<(), String> {
            for node in sim.world.cluster.nodes() {
                let r = node.reserved();
                let cap = node.capacity();
                if !(r.cpu <= cap.cpu && r.memory <= cap.memory) {
                    return Err(format!(
                        "{when}: node {:?} over-committed: reserved {:?} > capacity {:?}",
                        node.id, r, cap
                    ));
                }
            }
            for pod in sim.world.cluster.pods() {
                if let Some(node_id) = pod.node {
                    let cap = sim.world.cluster.node(node_id).capacity().cpu;
                    if pod.status.applied_cpu_limit > cap {
                        return Err(format!(
                            "{when}: pod {:?} applied limit {} exceeds node {:?} capacity {}",
                            pod.id, pod.status.applied_cpu_limit, node_id, cap
                        ));
                    }
                }
            }
            Ok(())
        };
        check(&sim, "after deploy")?;

        // Bursty traffic across all services, interleaved with checks.
        let rounds = g.usize(1, 4);
        for _ in 0..rounds {
            let mut at = sim.now();
            for _ in 0..g.usize(1, 12) {
                at = at + SimTime::from_millis_f64(g.f64(0.0, 3000.0));
                let svc = g.usize(0, n_services - 1);
                sim.submit_at(at, &format!("fn-{svc}"));
            }
            sim.run();
            check(&sim, "after burst")?;
        }

        // Let trailing parks/teardowns land, then re-check.
        let deadline = sim.now() + SimTime::from_secs(30);
        sim.run_until(deadline);
        sim.run();
        check(&sim, "after quiescence")?;
        Ok(())
    });
}

/// Differential oracle for the incremental fleet accounting: after every
/// randomized event sequence — deploys, bursts, partial drains (checked
/// *mid-flight*, with requests still in pods), full drains and
/// scale-to-zero teardowns, over 10–100-node uniform and calibrated
/// heterogeneous topologies under every routing policy — the incrementally
/// maintained per-node busy/committed/in-flight counters and the
/// per-service KPA counters must exactly equal a from-scratch rescan.
#[test]
fn prop_fleet_accounting_matches_rescan() {
    property("fleet_accounting_matches_rescan", 110, |g: &mut Gen| {
        let nodes = g.usize(10, 100);
        let topology = if g.bool() {
            Topology::uniform_paper(nodes)
        } else {
            Topology::hetero_preset(nodes)
        };
        let mut sim = Simulation::fleet(topology, g.u64(0, u64::MAX / 2));
        sim.world.routing = *g.choose(&RoutingPolicy::ALL);

        let check = |sim: &Simulation, when: &str| -> Result<(), String> {
            let oracle = sim.world.rescan_accounting();
            if let Some(d) = sim.world.fleet.diff(&oracle) {
                return Err(format!("{when}: {d}"));
            }
            for (name, svc) in &sim.world.services {
                let scan: usize = svc.pods.iter().map(|p| p.proxy.in_flight()).sum();
                if svc.in_flight_pods as usize != scan {
                    return Err(format!(
                        "{when}: {name} in_flight_pods {} != scanned {scan}",
                        svc.in_flight_pods
                    ));
                }
                let ready = svc
                    .pods
                    .iter()
                    .filter(|p| p.ready && !p.terminating)
                    .count();
                if svc.ready_count as usize != ready {
                    return Err(format!(
                        "{when}: {name} ready_count {} != scanned {ready}",
                        svc.ready_count
                    ));
                }
            }
            Ok(())
        };

        let n_services = g.usize(2, 8);
        for i in 0..n_services {
            // Every policy, including the forecast-driven pair: pool
            // refills/trims and speculative resizes must keep the
            // incremental counters consistent with the rescan too.
            let policy = *g.choose(&Policy::ALL);
            let kind = *g.choose(&[
                WorkloadKind::HelloWorld,
                WorkloadKind::Cpu,
                WorkloadKind::Io,
            ]);
            sim.deploy(&format!("fn-{i}"), WorkloadProfile::paper(kind), policy);
        }
        sim.run();
        check(&sim, "after deploy")?;

        let rounds = g.usize(1, 3);
        for round in 0..rounds {
            let mut at = sim.now();
            for _ in 0..g.usize(1, 16) {
                at = at + SimTime::from_millis_f64(g.f64(0.0, 4000.0));
                let svc = g.usize(0, n_services - 1);
                sim.submit_at(at, &format!("fn-{svc}"));
            }
            // Stop mid-flight (requests active inside pods, resizes and
            // startups pending) — the regime where incremental counters
            // could plausibly drift from the scans they replaced.
            let mid = sim.now() + SimTime::from_millis_f64(g.f64(5.0, 2500.0));
            sim.run_until(mid);
            check(&sim, &format!("mid-flight round {round}"))?;
            sim.run();
            check(&sim, &format!("drained round {round}"))?;
        }

        // Let scale-to-zero teardowns land, then re-check.
        let deadline = sim.now() + SimTime::from_secs(30);
        sim.run_until(deadline);
        sim.run();
        check(&sim, "after quiescence")?;
        Ok(())
    });
}

/// In-place policy safety: after any request pattern quiesces, the pod is
/// parked back at 1 m (the post-hook always wins eventually) and committed
/// CPU returns to the parked level.
#[test]
fn prop_inplace_always_reparks() {
    property("inplace_always_reparks", 15, |g: &mut Gen| {
        let mut sim = Simulation::paper(g.u64(0, u64::MAX / 2));
        sim.deploy(
            "fn",
            WorkloadProfile::paper(WorkloadKind::HelloWorld),
            Policy::InPlace,
        );
        sim.run();
        let mut at = sim.now();
        for _ in 0..g.usize(1, 16) {
            at = at + SimTime::from_millis_f64(g.f64(0.0, 400.0));
            sim.submit_at(at, "fn");
        }
        sim.run();
        // Let any trailing park resize land.
        let deadline = sim.now() + SimTime::from_secs(30);
        sim.run_until(deadline);
        sim.run();

        let svc = &sim.world.services["fn"];
        if svc.pods.len() != 1 {
            return Err(format!("expected 1 pod, got {}", svc.pods.len()));
        }
        let pod = svc.pods[0].pod;
        let applied = sim
            .world
            .cluster
            .pod(pod)
            .unwrap()
            .status
            .applied_cpu_limit;
        if applied != MilliCpu(1) {
            return Err(format!("pod not parked: applied={applied}"));
        }
        Ok(())
    });
}
