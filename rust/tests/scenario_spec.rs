//! Scenario API contract tests:
//!
//! 1. JSON round-trip: parse → serialize → parse is the identity.
//! 2. Strict parsing: unknown fields / invalid values are rejected with
//!    path-qualified errors.
//! 3. Preset equivalence: the `fleet` and `trace` presets reproduce the
//!    pre-redesign subcommand pipelines bit-for-bit (the acceptance
//!    criterion; the golden fixture pins the `paper` preset's substrate).
//! 4. The committed example scenario files under `examples/scenarios/`
//!    parse, expand and (for smoke) match the built-in preset.

use std::path::PathBuf;

use kinetic::cluster::topology::Topology;
use kinetic::experiments::fleet::{self, FleetConfig};
use kinetic::policy::Policy;
use kinetic::scenario::preset;
use kinetic::scenario::spec::TopologySpec;
use kinetic::scenario::{ScenarioEngine, ScenarioReport, ScenarioSpec, SpecError, WorkloadSource};
use kinetic::simclock::SimTime;
use kinetic::trace::generator::{TraceConfig, TraceGenerator};
use kinetic::trace::replay::replay;
use kinetic::util::json::Json;

// ------------------------------------------------------------- round trip

#[test]
fn every_preset_round_trips_through_json() {
    for name in preset::NAMES {
        let spec = preset::by_name(name).unwrap();
        let text = spec.to_json().to_string_pretty();
        let once = ScenarioSpec::parse(&text).unwrap();
        assert_eq!(spec, once, "{name}: parse(serialize(x)) != x");
        let twice = ScenarioSpec::parse(&once.to_json().to_string_pretty()).unwrap();
        assert_eq!(once, twice, "{name}: second round trip drifted");
    }
}

#[test]
fn sweep_and_knobs_round_trip() {
    let spec = ScenarioSpec::parse(
        r#"{
        "name": "tuned",
        "workload": {"type": "synthetic", "services": 12,
                     "rate_per_service": 0.4, "horizon_s": 120,
                     "mix": ["helloworld", "cpu"]},
        "topology": {"kind": "hetero", "nodes": 9},
        "policies": ["in-place", "warm"],
        "routing": ["least-loaded", "hybrid"],
        "autoscaler": {"max_scale": 8, "target_concurrency": 1.5,
                       "container_concurrency": 2, "stable_window_s": 12,
                       "parked_cpu_m": 100},
        "hybrid_weights": {"in_flight": 1000, "pressure_div": 2, "resize": 750},
        "seed": 7,
        "reps": 2,
        "sweep": [{"param": "rate_per_service", "values": [0.4, 0.8, 1.6]}]
    }"#,
    )
    .unwrap();
    let again = ScenarioSpec::parse(&spec.to_json().to_string_pretty()).unwrap();
    assert_eq!(spec, again);
    assert_eq!(spec.expand().unwrap().len(), 3);
    assert_eq!(spec.autoscaler.stable_window, Some(SimTime::from_secs(12)));
}

// --------------------------------------------------------- strict parsing

#[test]
fn unknown_fields_and_bad_values_fail_with_paths() {
    // Top-level typo.
    let e = ScenarioSpec::parse(
        r#"{"name":"x","workload":{"type":"synthetic","services":1,
            "rate_per_service":1,"horizon_s":10},"routnig":["hybrid"]}"#,
    )
    .unwrap_err()
    .to_string();
    assert!(e.contains("routnig") && e.contains("routing"), "{e}");

    // Nested typo inside autoscaler.
    let e = ScenarioSpec::parse(
        r#"{"name":"x","workload":{"type":"synthetic","services":1,
            "rate_per_service":1,"horizon_s":10},
            "autoscaler":{"max_scale":4,"stable_windows":30}}"#,
    )
    .unwrap_err()
    .to_string();
    assert!(e.contains("autoscaler") && e.contains("stable_windows"), "{e}");

    // Wrong type.
    let e = ScenarioSpec::parse(
        r#"{"name":"x","workload":{"type":"synthetic","services":"many",
            "rate_per_service":1,"horizon_s":10}}"#,
    )
    .unwrap_err()
    .to_string();
    assert!(e.contains("workload.services"), "{e}");

    // Out-of-range knob.
    let e = ScenarioSpec::parse(
        r#"{"name":"x","workload":{"type":"synthetic","services":1,
            "rate_per_service":1,"horizon_s":10},
            "autoscaler":{"panic_window_divisor":0}}"#,
    )
    .unwrap_err()
    .to_string();
    assert!(e.contains("panic_window_divisor") && e.contains("outside"), "{e}");

    // Bad routing name points at the element.
    let e = ScenarioSpec::parse(
        r#"{"name":"x","workload":{"type":"synthetic","services":1,
            "rate_per_service":1,"horizon_s":10},"routing":["nearest"]}"#,
    )
    .unwrap_err()
    .to_string();
    assert!(e.contains("routing[0]"), "{e}");

    // Not JSON at all.
    assert!(matches!(
        ScenarioSpec::parse("{"),
        Err(SpecError::Json(_))
    ));
}

// ----------------------------------------------------- preset equivalence

/// The `fleet` preset through the engine vs the pre-redesign pipeline
/// (`FleetConfig` + `fleet::run_all`), bit-for-bit, single routing.
#[test]
fn fleet_preset_matches_legacy_subcommand_pipeline() {
    let (nodes, services, rate, seconds, seed) = (4usize, 8usize, 0.1f64, 60u64, 11u64);
    let spec = preset::fleet(
        TopologySpec::Uniform { nodes },
        vec![kinetic::coordinator::accounting::RoutingPolicy::LeastLoaded],
        services,
        rate,
        seconds,
        seed,
    );
    let report = ScenarioEngine::run(&spec).unwrap();

    // What `kinetic fleet` ran before the redesign (knob defaults are the
    // old hard-wired constants).
    let legacy_cfg = FleetConfig {
        services,
        rate_per_service: rate,
        horizon: SimTime::from_secs(seconds),
        ..FleetConfig::base(Topology::uniform_paper(nodes), seed)
    };
    let legacy = fleet::run_all(&legacy_cfg);

    assert_eq!(report.rows.len(), legacy.len());
    for (got, want) in report.rows.iter().zip(&legacy) {
        assert_eq!(got.policy, want.policy);
        assert_eq!(got.completed, want.completed, "{:?}", want.policy);
        assert_eq!(got.failed, want.failed);
        assert_eq!(
            got.mean_ms.to_bits(),
            want.mean_ms.to_bits(),
            "{:?}: engine drifted from the legacy fleet pipeline",
            want.policy
        );
        assert_eq!(got.p99_ms.to_bits(), want.p99_ms.to_bits());
        assert_eq!(got.cold_starts, want.cold_starts);
        assert_eq!(
            got.avg_committed_mcpu.to_bits(),
            want.avg_committed_mcpu.to_bits()
        );
        assert_eq!(got.pods_created, want.pods_created);
    }
}

/// The routing sweep (`--routing all`) vs the legacy `routing_sweep`.
#[test]
fn fleet_preset_routing_sweep_matches_legacy() {
    let spec = preset::fleet(
        TopologySpec::Hetero { nodes: 3 },
        kinetic::coordinator::accounting::RoutingPolicy::ALL.to_vec(),
        6,
        0.1,
        30,
        5,
    );
    let report = ScenarioEngine::run(&spec).unwrap();
    let legacy_cfg = FleetConfig {
        services: 6,
        rate_per_service: 0.1,
        horizon: SimTime::from_secs(30),
        ..FleetConfig::base(Topology::hetero_preset(3), 5)
    };
    let legacy = fleet::routing_sweep(&legacy_cfg);
    assert_eq!(report.rows.len(), 9);
    assert_eq!(report.rows.len(), legacy.len());
    for (got, want) in report.rows.iter().zip(&legacy) {
        assert_eq!(got.routing, want.routing);
        assert_eq!(got.policy, want.policy);
        assert_eq!(got.mean_ms.to_bits(), want.mean_ms.to_bits());
        assert_eq!(got.completed, want.completed);
    }
}

/// The `trace` preset vs the pre-redesign pipeline (`TraceGenerator` +
/// `replay`), bit-for-bit per policy.
#[test]
fn trace_preset_matches_legacy_subcommand_pipeline() {
    let (functions, seconds, rate, seed) = (4usize, 120u64, 2.0f64, 3u64);
    let spec = preset::trace(functions, seconds, rate, seed);
    let report = ScenarioEngine::run(&spec).unwrap();

    let legacy_trace = TraceGenerator::new(TraceConfig {
        functions,
        peak_rate: rate,
        horizon: SimTime::from_secs(seconds),
        seed,
        ..TraceConfig::default()
    })
    .generate();

    // The preset stays the §3 triple (bit-identical to the legacy
    // subcommand); the predictive policies are opt-in via spec files.
    assert_eq!(report.rows.len(), Policy::PAPER.len());
    for (got, &policy) in report.rows.iter().zip(Policy::PAPER.iter()) {
        let want = replay(&legacy_trace, functions, policy, seed);
        assert_eq!(got.policy, policy);
        assert_eq!(got.completed, want.completed, "{policy:?}");
        assert_eq!(got.failed, want.failed);
        assert_eq!(
            got.mean_ms.to_bits(),
            want.mean_ms.to_bits(),
            "{policy:?}: engine drifted from the legacy trace pipeline"
        );
        assert_eq!(got.p99_ms.to_bits(), want.p99_ms.to_bits());
        assert_eq!(got.cold_starts, want.cold_starts);
        assert_eq!(got.pods_created, want.pods_created);
        assert_eq!(
            got.avg_committed_mcpu.to_bits(),
            want.avg_committed_mcpu.to_bits()
        );
    }
}

// ----------------------------------------------------- committed examples

fn scenarios_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../examples/scenarios")
}

#[test]
fn committed_example_scenarios_parse_and_expand() {
    let dir = scenarios_dir();
    let mut found = 0;
    for entry in std::fs::read_dir(&dir).expect("examples/scenarios exists") {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) != Some("json") {
            continue;
        }
        found += 1;
        let spec = ScenarioSpec::load(&path)
            .unwrap_or_else(|e| panic!("{} does not parse: {e}", path.display()));
        let variants = spec.expand().unwrap();
        assert!(!variants.is_empty(), "{}", path.display());
        // Canonical form round-trips.
        let again = ScenarioSpec::parse(&spec.to_json().to_string_pretty()).unwrap();
        assert_eq!(spec, again, "{}", path.display());
    }
    assert!(found >= 4, "expected the committed scenario set, found {found}");
}

#[test]
fn smoke_file_matches_builtin_preset() {
    let spec = ScenarioSpec::load(&scenarios_dir().join("smoke.json")).unwrap();
    assert_eq!(
        spec,
        preset::smoke(),
        "examples/scenarios/smoke.json and preset::smoke() must stay in lockstep"
    );
}

/// End-to-end: run the smoke file exactly as CI does, save the report,
/// reload it and validate the schema.
#[test]
fn smoke_scenario_report_validates_after_save() {
    let spec = ScenarioSpec::load(&scenarios_dir().join("smoke.json")).unwrap();
    let report = ScenarioEngine::run(&spec).unwrap();
    assert_eq!(report.rows.len(), 3);
    for r in &report.rows {
        assert_eq!(r.failed, 0);
        assert!(r.completed > 0);
    }
    let dir = std::env::temp_dir().join(format!("kinetic-smoke-{}", std::process::id()));
    let path = report.save(&dir).unwrap();
    let back = ScenarioReport::load(&path).unwrap();
    assert_eq!(back, report);
    ScenarioReport::validate(&Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap())
        .unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

/// The autoscaling-study spec the ROADMAP calls for is committed and
/// declares the target-concurrency × stable-window grid.
#[test]
fn autoscaling_sweep_spec_declares_the_roadmap_grid() {
    let spec = ScenarioSpec::load(&scenarios_dir().join("autoscaling_sweep.json")).unwrap();
    let params: Vec<&str> = spec.sweep.iter().map(|s| s.param.as_str()).collect();
    assert!(params.contains(&"target_concurrency"), "{params:?}");
    assert!(params.contains(&"stable_window_s"), "{params:?}");
    match spec.workload {
        WorkloadSource::Synthetic { .. } => {}
        other => panic!("expected a synthetic fleet source, got {other:?}"),
    }
}

/// The predictive study compares both forecast-driven policies against
/// the full §3 triple over the committed Azure sample trace, sweeping the
/// speculation horizon.
#[test]
fn predictive_azure_spec_compares_new_policies_to_the_triple() {
    let spec = ScenarioSpec::load(&scenarios_dir().join("predictive_azure.json")).unwrap();
    for p in Policy::ALL {
        assert!(
            spec.policies.contains(&p),
            "predictive_azure must include {}",
            p.name()
        );
    }
    assert!(matches!(spec.workload, WorkloadSource::TraceFile { .. }));
    assert!(
        spec.sweep.iter().any(|s| s.param == "forecast_horizon_ms"),
        "must sweep the speculation horizon"
    );
    assert_eq!(spec.forecast.pool_size, 2);
    // 3 horizon values × 1 routing × 5 policies × 1 rep = 15 runs.
    assert_eq!(spec.expand().unwrap().len(), 3);
}

/// The routing-saturation spec sweeps every routing policy at saturating
/// rates on a heterogeneous fleet with tuned hybrid weights.
#[test]
fn routing_saturation_spec_covers_all_policies_at_load() {
    let spec = ScenarioSpec::load(&scenarios_dir().join("routing_saturation.json")).unwrap();
    assert_eq!(
        spec.routing.len(),
        3,
        "must compare least-loaded, locality and hybrid"
    );
    assert!(matches!(spec.topology, TopologySpec::Hetero { .. }));
    assert_ne!(
        spec.hybrid,
        kinetic::coordinator::accounting::HybridWeights::default(),
        "ships tuned hybrid weights"
    );
    let rates: Vec<f64> = spec
        .sweep
        .iter()
        .find(|s| s.param == "rate_per_service")
        .expect("sweeps rate_per_service")
        .values
        .clone();
    assert!(rates.iter().any(|&r| r >= 1.0), "must reach saturating rates");
}
