//! Integration locks for the sharded multi-coordinator runtime:
//!
//! 1. The acceptance criterion — `kinetic run --shards N` emits a
//!    ScenarioReport **byte-identical** to `--shards 1` for N ∈ {2, 4} on
//!    the smoke, predictive and node-crash studies (the sharded sibling of
//!    `analysis.rs::smoke_report_is_byte_identical_across_thread_counts`).
//! 2. Shard × fault interplay: fault node indices are validated against
//!    the *global* topology before shard planning, and crash-evicted pods
//!    reschedule deterministically regardless of the shard count.
//! 3. The shard planner's public contract: stable assignment, manifest
//!    round-trip, empty shards are harmless.

use kinetic::scenario::preset;
use kinetic::scenario::{ScenarioEngine, ScenarioReport, ScenarioSpec};
use kinetic::shard::{ShardPlan, MANIFEST_KIND};

/// Runs `spec` under the sharded runtime at the given shard count, via
/// the same entry point the CLI `--shards` flag uses. The spec itself is
/// untouched, so the spec echo inside the report is identical across
/// counts — any byte difference is a real divergence in the rows.
fn run_sharded(spec: &ScenarioSpec, shards: u32) -> ScenarioReport {
    ScenarioEngine::run_with_options(spec, 1, Some(shards)).unwrap()
}

fn assert_identical_across_shard_counts(spec: &ScenarioSpec) -> ScenarioReport {
    let one = run_sharded(spec, 1);
    for n in [2u32, 4] {
        let sharded = run_sharded(spec, n);
        assert_eq!(
            one.to_json().to_string_pretty().as_bytes(),
            sharded.to_json().to_string_pretty().as_bytes(),
            "'{}' report at --shards {n} diverged from --shards 1",
            spec.name
        );
    }
    one
}

/// Acceptance criterion on the smoke preset: byte-identical at 1/2/4
/// shards, and the run completes real work under every policy.
#[test]
fn smoke_report_is_byte_identical_across_shard_counts() {
    let spec = preset::by_name("smoke").expect("smoke preset exists");
    let report = assert_identical_across_shard_counts(&spec);
    assert!(!report.rows.is_empty());
    for r in &report.rows {
        assert!(r.completed > 0, "{:?}", r.policy);
    }
}

/// Acceptance criterion on a predictive study: the forecast-driven
/// policies (pre-warm pool + speculative resize) ride the sharded runtime
/// with the same determinism guarantee.
#[test]
fn predictive_report_is_byte_identical_across_shard_counts() {
    let spec = ScenarioSpec::parse(
        r#"{
        "name": "predictive-sharded",
        "workload": {"type": "synthetic", "services": 4,
                     "rate_per_service": 0.2, "horizon_s": 40},
        "topology": {"kind": "uniform", "nodes": 2},
        "policies": ["cold", "pooled", "predictive-inplace"],
        "forecast": {"pool_size": 2, "horizon_ms": 2000},
        "reps": 2
    }"#,
    )
    .unwrap();
    let report = assert_identical_across_shard_counts(&spec);
    assert_eq!(report.rows.len(), 6); // 3 policies × 2 reps
    for r in &report.rows {
        assert!(r.completed > 0, "{:?}", r.policy);
    }
}

/// A mid-run node crash taking out an entire cell: the cross-shard
/// escalation path (reschedule into a sibling cell one lookahead later)
/// must be byte-identical at any shard count too.
fn crash_spec() -> ScenarioSpec {
    ScenarioSpec::parse(
        r#"{
        "name": "crash-sharded",
        "workload": {"type": "synthetic", "services": 6,
                     "rate_per_service": 0.4, "horizon_s": 45},
        "topology": {"kind": "uniform", "nodes": 3},
        "policies": ["warm", "in-place"],
        "reps": 2,
        "faults": {
            "node_crashes": [{"node": 2, "at_s": 8, "down_s": 12}],
            "crash_requests": "fail"
        }
    }"#,
    )
    .unwrap()
}

/// The shard × fault regression pin: crash-evicted pods reschedule
/// deterministically regardless of shard count — `pods_rescheduled` (and
/// every other fault counter, via the byte comparison) is equal at
/// `--shards 1` and `--shards 4`.
#[test]
fn crash_recovery_is_identical_across_shard_counts() {
    let spec = crash_spec();
    let one = assert_identical_across_shard_counts(&spec);
    let four = run_sharded(&spec, 4);
    assert!(
        one.rows.iter().any(|r| r.pods_evicted > 0),
        "the node crash must evict at least one pod somewhere in the grid"
    );
    for (a, b) in one.rows.iter().zip(four.rows.iter()) {
        assert_eq!(
            a.pods_rescheduled, b.pods_rescheduled,
            "reschedule count diverged at --shards 4 for {:?}",
            a.policy
        );
        assert_eq!(a.pods_evicted, b.pods_evicted, "{:?}", a.policy);
    }
}

/// Fault node indices are validated against the GLOBAL topology before
/// any shard planning happens: a 3-node fleet rejects `node: 7` with the
/// same path-qualified error whether or not the run is sharded.
#[test]
fn fault_node_validation_uses_the_global_topology_under_sharding() {
    let spec = ScenarioSpec::parse(
        r#"{
        "name": "bad-crash",
        "workload": {"type": "synthetic", "services": 2,
                     "rate_per_service": 0.2, "horizon_s": 20},
        "topology": {"kind": "uniform", "nodes": 3},
        "policies": ["in-place"],
        "faults": {"node_crashes": [{"node": 7, "at_s": 5, "down_s": 5}]}
    }"#,
    )
    .unwrap();
    for shards in [None, Some(2)] {
        let e = ScenarioEngine::run_with_options(&spec, 1, shards)
            .unwrap_err()
            .to_string();
        assert!(
            e.contains("node 7") && e.contains("3 node(s)"),
            "error must name the fault node and the global topology: {e}"
        );
    }
}

/// The spec-level `shards` knob drives the sharded runtime without any
/// CLI flag, echoes through the report, and is beaten by the override.
#[test]
fn spec_shards_knob_matches_the_cli_override() {
    let mut spec = preset::by_name("smoke").unwrap();
    spec.shards = Some(2);
    assert!(
        spec.to_json().to_string_pretty().contains("\"shards\": 2"),
        "the knob must echo through the canonical spec form"
    );
    let via_knob = ScenarioEngine::run(&spec).unwrap();
    // Same rows whether the count comes from the knob or the override
    // (the spec echo differs by exactly the `shards` key, so compare rows).
    let via_flag = run_sharded(&spec, 2);
    assert_eq!(via_knob.rows, via_flag.rows);
    // The CLI override wins over the knob: --shards 4 on a shards:2 spec
    // is still byte-identical (determinism), so rows match as well.
    let overridden = ScenarioEngine::run_with_options(&spec, 1, Some(4)).unwrap();
    assert_eq!(via_knob.rows, overridden.rows);
}

/// Closed-loop specs run the paper's single-node rig; asking for shards
/// there is a spec error, not a silent fallback.
#[test]
fn closed_loop_rejects_shards() {
    let spec = preset::paper(2, 42);
    let e = ScenarioEngine::run_with_options(&spec, 1, Some(2))
        .unwrap_err()
        .to_string();
    assert!(e.contains("shards"), "{e}");
    // And via the spec knob, without any CLI flag.
    let mut spec = preset::paper(2, 42);
    spec.shards = Some(2);
    let e = ScenarioEngine::run(&spec).unwrap_err().to_string();
    assert!(e.contains("shards"), "{e}");
}

/// Planner contract at integration level: assignments depend only on the
/// node id and shard count, the manifest round-trips, and shard counts
/// beyond the cell count leave empty shards that change nothing.
#[test]
fn shard_planner_contract() {
    use kinetic::cluster::Topology;
    let topo = Topology::uniform_paper(5);
    let plan = ShardPlan::new(&topo, 3);
    assert_eq!(plan.cells(), 5);
    // Stable: recomputing yields the same assignment.
    assert_eq!(plan.shard_of, ShardPlan::new(&topo, 3).shard_of);
    // Manifest round-trip preserves the plan exactly.
    let services: Vec<String> = (0..4).map(|i| format!("svc-{i}")).collect();
    let m = plan.manifest(&services);
    assert_eq!(m.req_str("kind").unwrap(), MANIFEST_KIND);
    let back = ShardPlan::from_manifest(&m).unwrap();
    assert_eq!(back.shards, plan.shards);
    assert_eq!(back.shard_of, plan.shard_of);
    // More shards than cells: every cell still lands somewhere valid.
    let wide = ShardPlan::new(&topo, 64);
    assert!(wide.shard_of.iter().all(|&s| s < 64));
}
